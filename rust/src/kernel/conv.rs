//! Direct GS-SOC orthogonal-convolution runtime — the production path for
//! the paper's third empirical pillar (§6.3, Eq. 2): same-padded
//! multichannel convolution applied in `O(c_out·(c_in/g)·k²·H·W)` per
//! image, the truncated convolution exponential streamed term by term, and
//! channel shuffles as plane relayouts — without ever materializing the
//! `(c·H·W)²` doubly-Toeplitz matrix that `gs/conv.rs` builds.
//!
//! The exact dense code in [`crate::gs::conv`] survives solely as the
//! property-test oracle: every path here is tested (with shrinking)
//! against `ConvKernel::to_matrix` / `mat_exp`, including rectangular
//! `H≠W` grids, `c_out≠c_in` kernels and grouped structure.
//!
//! Layout convention: an image batch is a [`Mat`] of shape
//! `[c·h·w, t]` — each column is one `vec(X)` in the row-major
//! `[channel, row, col]` order `gs/conv.rs` uses, so the serving engine's
//! `[d, batch]` activations flow through unchanged.
//!
//! Two kernels, chosen by [`KernelCtx::plan_conv`]:
//!
//! - **direct** — a fused AXPY loop: for each `(o, i, p, q)` tap and each
//!   valid output row `y`, one contiguous `f · x[row]`-accumulate over the
//!   `(x_end-x_start)·t` span (taps with zero weight are skipped, which
//!   makes skew/grouped kernels cheaper for free). Best for small
//!   channel counts where im2col's patch copy dominates.
//! - **im2col** — per group, gather patches into a `[gi·k², h·w·t]`
//!   matrix and hand `[go, gi·k²] · patches` to the cache-blocked GEMM
//!   dispatcher, which also provides row-panel parallelism for large
//!   shapes.
//!
//! Rust ↔ Pallas/JAX counterpart (DESIGN.md §Perf): `conv_apply` ↔
//! `lipconvnet._grouped_conv` (XLA `conv_general_dilated`);
//! `conv_exp_apply` ↔ `lipconvnet.conv_exp`; `channel_shuffle_apply` ↔
//! `lipconvnet.channel_shuffle`; [`GsSocLayer::apply`] ↔
//! `lipconvnet.gs_soc_layer`.

use crate::gs::conv::ConvKernel;
use crate::gs::{perm_kn, Perm};
use crate::linalg::Mat;
use crate::util::rng::Rng;

use super::dispatch::{ConvKind, KernelCtx};

/// A grouped same-padded conv kernel stored densely *within* groups:
/// row-major `[c_out, c_in/groups, k, k]` — output channel `o` (in group
/// `g = o / (c_out/groups)`) couples only to the `c_in/groups` input
/// channels of group `g`. `groups == 1` is a plain dense kernel.
#[derive(Clone, Debug)]
pub struct GroupedConv {
    pub groups: usize,
    pub c_out: usize,
    pub c_in: usize,
    pub k: usize,
    /// Row-major `[c_out, c_in/groups, k, k]`.
    pub w: Vec<f64>,
}

impl GroupedConv {
    pub fn zeros(c_out: usize, c_in: usize, k: usize, groups: usize) -> GroupedConv {
        assert!(k % 2 == 1, "same-padded conv needs odd kernel (got k={k})");
        assert!(
            groups > 0 && c_out % groups == 0 && c_in % groups == 0,
            "groups {groups} must divide c_out {c_out} and c_in {c_in}"
        );
        GroupedConv {
            groups,
            c_out,
            c_in,
            k,
            w: vec![0.0; c_out * (c_in / groups) * k * k],
        }
    }

    pub fn randn(
        c_out: usize,
        c_in: usize,
        k: usize,
        groups: usize,
        std: f64,
        rng: &mut Rng,
    ) -> GroupedConv {
        let mut c = GroupedConv::zeros(c_out, c_in, k, groups);
        for v in c.w.iter_mut() {
            *v = rng.normal() * std;
        }
        c
    }

    /// From a flat f32 slab (adapter parameters), row-major
    /// `[c_out, c_in/groups, k, k]`.
    pub fn from_f32(
        c_out: usize,
        c_in: usize,
        k: usize,
        groups: usize,
        raw: &[f32],
    ) -> GroupedConv {
        let mut c = GroupedConv::zeros(c_out, c_in, k, groups);
        assert_eq!(
            raw.len(),
            c.w.len(),
            "grouped conv slab has {} floats, expected c_out·(c_in/groups)·k² = {}",
            raw.len(),
            c.w.len()
        );
        for (a, &b) in c.w.iter_mut().zip(raw.iter()) {
            *a = b as f64;
        }
        c
    }

    /// Input channels per group.
    #[inline]
    pub fn gi(&self) -> usize {
        self.c_in / self.groups
    }

    /// Output channels per group.
    #[inline]
    pub fn go(&self) -> usize {
        self.c_out / self.groups
    }

    /// Tap weight for output channel `o` and the `il`-th input channel of
    /// `o`'s group.
    #[inline]
    pub fn at(&self, o: usize, il: usize, p: usize, q: usize) -> f64 {
        self.w[((o * self.gi() + il) * self.k + p) * self.k + q]
    }

    #[inline]
    pub fn at_mut(&mut self, o: usize, il: usize, p: usize, q: usize) -> &mut f64 {
        let gi = self.gi();
        &mut self.w[((o * gi + il) * self.k + p) * self.k + q]
    }

    /// Keep only the within-group taps of a dense [`ConvKernel`] (the
    /// grouped projection; cross-group taps are discarded).
    pub fn from_dense(kern: &ConvKernel, groups: usize) -> GroupedConv {
        let mut out = GroupedConv::zeros(kern.c_out, kern.c_in, kern.k, groups);
        let (gi, go) = (out.gi(), out.go());
        for g in 0..groups {
            for ol in 0..go {
                for il in 0..gi {
                    for p in 0..kern.k {
                        for q in 0..kern.k {
                            *out.at_mut(g * go + ol, il, p, q) =
                                kern.at(g * go + ol, g * gi + il, p, q);
                        }
                    }
                }
            }
        }
        out
    }

    /// Expand to the dense `[c_out, c_in, k, k]` form (cross-group taps
    /// zero) — the bridge to the `gs/conv.rs` oracle.
    pub fn to_dense(&self) -> ConvKernel {
        let mut out = ConvKernel::zeros(self.c_out, self.c_in, self.k);
        let (gi, go) = (self.gi(), self.go());
        for g in 0..self.groups {
            for ol in 0..go {
                for il in 0..gi {
                    for p in 0..self.k {
                        for q in 0..self.k {
                            *out.at_mut(g * go + ol, g * gi + il, p, q) =
                                self.at(g * go + ol, il, p, q);
                        }
                    }
                }
            }
        }
        out
    }

    /// The paper's `ConvTranspose` restricted to the grouped support
    /// (which is closed under it): `M'_{i,o,p,q} = M_{o,i,k-1-p,k-1-q}`.
    /// The Eq. 2 matrix of the result is exactly the transpose of this
    /// kernel's Eq. 2 matrix.
    pub fn conv_transpose(&self) -> GroupedConv {
        let mut out = GroupedConv::zeros(self.c_in, self.c_out, self.k, self.groups);
        let (gi, go) = (self.gi(), self.go());
        for g in 0..self.groups {
            for ol in 0..go {
                for il in 0..gi {
                    for p in 0..self.k {
                        for q in 0..self.k {
                            *out.at_mut(
                                g * gi + il,
                                ol,
                                self.k - 1 - p,
                                self.k - 1 - q,
                            ) = self.at(g * go + ol, il, p, q);
                        }
                    }
                }
            }
        }
        out
    }

    /// SOC parametrization `L = M - ConvTranspose(M)` (requires
    /// `c_in == c_out`): the Eq. 2 matrix becomes skew-symmetric, so the
    /// convolution exponential is orthogonal.
    pub fn skew_symmetrize(&self) -> GroupedConv {
        assert_eq!(
            self.c_in, self.c_out,
            "skew_symmetrize needs a square kernel (c_in {} vs c_out {})",
            self.c_in, self.c_out
        );
        let t = self.conv_transpose();
        let mut out = self.clone();
        for (a, b) in out.w.iter_mut().zip(t.w.iter()) {
            *a -= b;
        }
        out
    }
}

/// Same-padded grouped convolution of a `[c_in·h·w, t]` batch, dispatched
/// between the direct AXPY loop and im2col-into-blocked-GEMM by
/// [`KernelCtx::plan_conv`].
pub fn conv_apply(kern: &GroupedConv, x: &Mat, h: usize, w: usize, ctx: &KernelCtx) -> Mat {
    assert_eq!(
        x.rows,
        kern.c_in * h * w,
        "conv apply shape mismatch: X has {} rows, kernel expects c_in·h·w = {}·{}·{} = {}",
        x.rows,
        kern.c_in,
        h,
        w,
        kern.c_in * h * w
    );
    match ctx.plan_conv(kern.c_out, kern.gi(), kern.k, h * w, x.cols) {
        ConvKind::Direct => conv_direct(kern, x, h, w),
        ConvKind::Im2col => conv_im2col(kern, x, h, w, ctx),
    }
}

/// Valid output range along one axis for tap offset `d = p - half`:
/// output coordinate `y` contributes iff `0 <= y + d < extent`.
#[inline]
fn tap_range(d: isize, extent: usize) -> (usize, usize) {
    let lo = (-d).max(0) as usize;
    let hi = ((extent as isize - d).min(extent as isize)).max(0) as usize;
    (lo, hi)
}

/// Direct path: one contiguous AXPY per `(o, i, p, q, y)` — for fixed
/// output row `y` the valid columns `x_start..x_end` are a contiguous
/// span of both the input and the output buffer, `(x_end-x_start)·t`
/// elements long. Zero taps are skipped (skew kernels have a zero center
/// tap by construction).
fn conv_direct(kern: &GroupedConv, x: &Mat, h: usize, w: usize) -> Mat {
    let (gi, go) = (kern.gi(), kern.go());
    let hw = h * w;
    let t = x.cols;
    let k = kern.k;
    let half = (k - 1) / 2;
    let mut out = Mat::zeros(kern.c_out * hw, t);
    for g in 0..kern.groups {
        for ol in 0..go {
            let o = g * go + ol;
            for il in 0..gi {
                let ci = g * gi + il;
                for p in 0..k {
                    let dy = p as isize - half as isize;
                    let (y0, y1) = tap_range(dy, h);
                    for q in 0..k {
                        let f = kern.at(o, il, p, q);
                        if f == 0.0 {
                            continue;
                        }
                        let dx = q as isize - half as isize;
                        let (x0, x1) = tap_range(dx, w);
                        if x1 <= x0 {
                            continue;
                        }
                        let n = (x1 - x0) * t;
                        for y in y0..y1 {
                            let sy = (y as isize + dy) as usize;
                            let sx0 = (x0 as isize + dx) as usize;
                            let src0 = (ci * hw + sy * w + sx0) * t;
                            let dst0 = (o * hw + y * w + x0) * t;
                            let src = &x.data[src0..src0 + n];
                            let dst = &mut out.data[dst0..dst0 + n];
                            for (a, &b) in dst.iter_mut().zip(src.iter()) {
                                *a += f * b;
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

/// im2col path: per group, gather patches into `[gi·k², h·w·t]` (zeros at
/// the padding border) and run `[go, gi·k²] · patches` through the GEMM
/// dispatcher. The group's output block `[go, h·w·t]` is row-major
/// exactly the `go·h·w` output rows, so it lands with one memcpy.
fn conv_im2col(kern: &GroupedConv, x: &Mat, h: usize, w: usize, ctx: &KernelCtx) -> Mat {
    let (gi, go) = (kern.gi(), kern.go());
    let hw = h * w;
    let t = x.cols;
    let k = kern.k;
    let half = (k - 1) / 2;
    let mut out = Mat::zeros(kern.c_out * hw, t);
    let kslab = gi * k * k;
    for g in 0..kern.groups {
        // Per-call copy of the group's kernel slab (go·gi·k² doubles) —
        // a 1/(hw·t) fraction of the GEMM's go·gi·k²·hw·t flops, so a
        // FusedPlan-style amortization is not warranted here.
        let kg = Mat {
            rows: go,
            cols: kslab,
            data: kern.w[g * go * kslab..(g + 1) * go * kslab].to_vec(),
        };
        let mut pg = Mat::zeros(kslab, hw * t);
        for il in 0..gi {
            let ci = g * gi + il;
            for p in 0..k {
                let dy = p as isize - half as isize;
                let (y0, y1) = tap_range(dy, h);
                for q in 0..k {
                    let dx = q as isize - half as isize;
                    let (x0, x1) = tap_range(dx, w);
                    if x1 <= x0 {
                        continue;
                    }
                    let r = (il * k + p) * k + q;
                    let n = (x1 - x0) * t;
                    for y in y0..y1 {
                        let sy = (y as isize + dy) as usize;
                        let sx0 = (x0 as isize + dx) as usize;
                        let src0 = (ci * hw + sy * w + sx0) * t;
                        let dst0 = r * hw * t + (y * w + x0) * t;
                        pg.data[dst0..dst0 + n].copy_from_slice(&x.data[src0..src0 + n]);
                    }
                }
            }
        }
        let yg = ctx.gemm(&kg, &pg);
        out.data[g * go * hw * t..(g + 1) * go * hw * t].copy_from_slice(&yg.data);
    }
    out
}

/// Single-image convenience: `x: [c_in, h, w]` flat → `[c_out, h, w]`
/// flat (the `vec(X)` convention of `gs/conv.rs`).
pub fn conv_image(kern: &GroupedConv, x: &[f64], h: usize, w: usize, ctx: &KernelCtx) -> Vec<f64> {
    let xm = Mat::from_rows(x.len(), 1, x);
    conv_apply(kern, &xm, h, w, ctx).data
}

/// Batched NCHW convenience: `x: [n, c_in, h, w]` flat → `[n, c_out, h,
/// w]` flat. Internally transposes to the `[c·h·w, n]` column layout the
/// kernels stream over, so one dispatch serves the whole batch.
pub fn conv_apply_nchw(
    kern: &GroupedConv,
    x: &[f64],
    n: usize,
    h: usize,
    w: usize,
    ctx: &KernelCtx,
) -> Vec<f64> {
    let d_in = kern.c_in * h * w;
    assert_eq!(
        x.len(),
        n * d_in,
        "conv NCHW shape mismatch: input has {} elements, expected n·c_in·h·w = {}·{}·{}·{} = {}",
        x.len(),
        n,
        kern.c_in,
        h,
        w,
        n * d_in
    );
    let mut xm = Mat::zeros(d_in, n);
    for j in 0..n {
        for (i, &v) in x[j * d_in..(j + 1) * d_in].iter().enumerate() {
            xm[(i, j)] = v;
        }
    }
    let y = conv_apply(kern, &xm, h, w, ctx);
    let d_out = kern.c_out * h * w;
    let mut out = vec![0.0; n * d_out];
    for j in 0..n {
        for i in 0..d_out {
            out[j * d_out + i] = y[(i, j)];
        }
    }
    out
}

/// Streaming convolution exponential (Definition 6.1):
/// `exp(L) X = X + LX/1! + L²X/2! + …` truncated at `terms`, applied as
/// `terms` grouped conv passes — never forming `mat_exp` of the
/// `(c·h·w)²` Eq. 2 matrix.
pub fn conv_exp_apply(
    kern: &GroupedConv,
    x: &Mat,
    h: usize,
    w: usize,
    terms: usize,
    ctx: &KernelCtx,
) -> Mat {
    assert_eq!(
        kern.c_in, kern.c_out,
        "conv exponential needs a square kernel (c_in {} vs c_out {})",
        kern.c_in, kern.c_out
    );
    assert_eq!(
        x.rows,
        kern.c_in * h * w,
        "conv_exp shape mismatch: X has {} rows, kernel expects c_in·h·w = {}·{}·{} = {}",
        x.rows,
        kern.c_in,
        h,
        w,
        kern.c_in * h * w
    );
    let mut acc = x.clone();
    let mut term = x.clone();
    for n in 1..=terms {
        term = conv_apply(kern, &term, h, w, ctx);
        let inv = 1.0 / n as f64;
        for v in term.data.iter_mut() {
            *v *= inv;
        }
        for (a, &b) in acc.data.iter_mut().zip(term.data.iter()) {
            *a += b;
        }
    }
    acc
}

/// Channel shuffle fast path: channel `i`'s `h·w` rows move wholesale to
/// channel `chperm.sigma[i]` — one `h·w·t` memcpy per channel instead of
/// a `(c·h·w)²` permutation-matrix product.
pub fn channel_shuffle_apply(chperm: &Perm, x: &Mat, hw: usize) -> Mat {
    assert_eq!(
        x.rows,
        chperm.n() * hw,
        "channel shuffle shape mismatch: X has {} rows, perm expects c·h·w = {}·{} = {}",
        x.rows,
        chperm.n(),
        hw,
        chperm.n() * hw
    );
    let t = x.cols;
    let plane = hw * t;
    let mut out = Mat::zeros(x.rows, t);
    for (i, &dst) in chperm.sigma.iter().enumerate() {
        out.data[dst * plane..(dst + 1) * plane]
            .copy_from_slice(&x.data[i * plane..(i + 1) * plane]);
    }
    out
}

/// One GS-SOC layer (§6.3, Eq. 3 factor): `P_out · exp(L) · P_in` with a
/// grouped skew kernel `L` — applied in a single streaming pass (channel
/// relayout in, truncated exponential through the grouped conv, relayout
/// out), never materializing the dense operator.
#[derive(Clone, Debug)]
pub struct GsSocLayer {
    /// Channel permutation applied before the exponential.
    pub p_in: Perm,
    /// Grouped, skew-symmetrized (square) conv kernel.
    pub kern: GroupedConv,
    /// Channel permutation applied after the exponential.
    pub p_out: Perm,
    pub h: usize,
    pub w: usize,
    /// Taylor terms of the truncated convolution exponential.
    pub terms: usize,
}

impl GsSocLayer {
    pub fn new(
        p_in: Perm,
        kern: GroupedConv,
        p_out: Perm,
        h: usize,
        w: usize,
        terms: usize,
    ) -> GsSocLayer {
        assert_eq!(
            kern.c_in, kern.c_out,
            "GS-SOC layer needs a square kernel (c_in {} vs c_out {})",
            kern.c_in, kern.c_out
        );
        assert_eq!(p_in.n(), kern.c_in, "P_in size must match channel count");
        assert_eq!(p_out.n(), kern.c_out, "P_out size must match channel count");
        assert!(terms >= 1, "conv exponential needs at least one term");
        GsSocLayer {
            p_in,
            kern,
            p_out,
            h,
            w,
            terms,
        }
    }

    /// Random layer: grouped Gaussian kernel, skew-symmetrized; shuffles
    /// are the paper's `P_(groups, c)` and its inverse.
    pub fn random(
        c: usize,
        k: usize,
        groups: usize,
        h: usize,
        w: usize,
        terms: usize,
        std: f64,
        rng: &mut Rng,
    ) -> GsSocLayer {
        let kern = GroupedConv::randn(c, c, k, groups, std, rng).skew_symmetrize();
        let p = perm_kn(groups, c);
        GsSocLayer::new(p.clone(), kern, p.inverse(), h, w, terms)
    }

    /// Channel count.
    pub fn c(&self) -> usize {
        self.kern.c_in
    }

    /// Flat activation dimension `c·h·w`.
    pub fn d(&self) -> usize {
        self.c() * self.h * self.w
    }

    /// Apply to a `[c·h·w, t]` batch.
    pub fn apply(&self, x: &Mat, ctx: &KernelCtx) -> Mat {
        let hw = self.h * self.w;
        let cur = if self.p_in.is_identity() {
            conv_exp_apply(&self.kern, x, self.h, self.w, self.terms, ctx)
        } else {
            let shuffled = channel_shuffle_apply(&self.p_in, x, hw);
            conv_exp_apply(&self.kern, &shuffled, self.h, self.w, self.terms, ctx)
        };
        if self.p_out.is_identity() {
            cur
        } else {
            channel_shuffle_apply(&self.p_out, &cur, hw)
        }
    }

    /// The exact adjoint layer: `(P_out exp(L) P_in)ᵀ =
    /// P_inᵀ exp(Lᵀ) P_outᵀ`, with `Lᵀ` realized by [`GroupedConv::
    /// conv_transpose`] (for a skew kernel, `Lᵀ = -L`). Because
    /// `(Lⁿ)ᵀ = (Lᵀ)ⁿ`, the *truncated* series transposes term by term,
    /// so `⟨apply(x), y⟩ = ⟨x, transposed().apply(y)⟩` holds exactly —
    /// this is what the power-iteration certifier iterates.
    pub fn transposed(&self) -> GsSocLayer {
        GsSocLayer::new(
            self.p_out.inverse(),
            self.kern.conv_transpose(),
            self.p_in.inverse(),
            self.h,
            self.w,
            self.terms,
        )
    }

    /// Dense oracle: the exact `d×d` matrix of this layer, assembled from
    /// the `gs/conv.rs` Eq. 2 machinery with the *same* series truncation
    /// as [`GsSocLayer::apply`] — used by the property tests and the
    /// merge-path checks, never on the request path.
    pub fn to_matrix(&self) -> Mat {
        use crate::gs::conv::channel_shuffle_perm;
        let d = self.d();
        let m = self.kern.to_dense().to_matrix(self.h, self.w);
        let mut acc = Mat::eye(d);
        let mut term = Mat::eye(d);
        for n in 1..=self.terms {
            term = m.matmul(&term).scale(1.0 / n as f64);
            acc = &acc + &term;
        }
        let pin = channel_shuffle_perm(&self.p_in, self.h, self.w);
        let pout = channel_shuffle_perm(&self.p_out, self.h, self.w);
        // P_out · (E · P_in): apply_cols is `A·P`, apply_rows is `P·A`.
        pout.apply_rows(&pin.apply_cols(&acc))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::gemm::gemm_naive;
    use crate::util::prop;

    /// Context forcing the direct path.
    fn direct_ctx() -> KernelCtx {
        KernelCtx {
            naive_below_flops: usize::MAX,
            ..KernelCtx::default()
        }
    }

    /// Context forcing the im2col path (and its GEMM dispatch).
    fn im2col_ctx() -> KernelCtx {
        KernelCtx {
            naive_below_flops: 0,
            ..KernelCtx::default()
        }
    }

    #[derive(Debug, Clone, Copy)]
    struct ConvCase {
        c_out: usize,
        c_in: usize,
        k: usize,
        h: usize,
        w: usize,
        groups: usize,
        t: usize,
        seed: u64,
    }

    fn shrink_conv(c: &ConvCase) -> Vec<ConvCase> {
        let mut out = Vec::new();
        for t in prop::shrink_usize(c.t, 1) {
            out.push(ConvCase { t, ..*c });
        }
        for h in prop::shrink_usize(c.h, 1) {
            out.push(ConvCase { h, ..*c });
        }
        for w in prop::shrink_usize(c.w, 1) {
            out.push(ConvCase { w, ..*c });
        }
        // Channel counts shrink toward `groups` (must stay divisible).
        for f in prop::shrink_usize(c.c_out / c.groups, 1) {
            out.push(ConvCase { c_out: f * c.groups, ..*c });
        }
        for f in prop::shrink_usize(c.c_in / c.groups, 1) {
            out.push(ConvCase { c_in: f * c.groups, ..*c });
        }
        if c.k > 1 {
            out.push(ConvCase { k: c.k - 2, ..*c });
        }
        out
    }

    fn gen_conv(rng: &mut Rng) -> ConvCase {
        let groups = prop::size_in(rng, 1, 3);
        ConvCase {
            c_out: groups * prop::size_in(rng, 1, 3),
            c_in: groups * prop::size_in(rng, 1, 3),
            k: 2 * prop::size_in(rng, 0, 1) + 1, // 1 or 3
            h: prop::size_in(rng, 1, 4),
            w: prop::size_in(rng, 1, 5), // often ≠ h: rectangular grids
            groups,
            t: prop::size_in(rng, 1, 4),
            seed: rng.next_u64(),
        }
    }

    #[test]
    fn direct_and_im2col_match_the_eq2_oracle() {
        // Oracle: the exact doubly-Toeplitz matrix of gs/conv.rs times the
        // batch, via the naive GEMM — independent of everything under test.
        prop::check_shrunk(
            "conv_apply == to_matrix · X (direct & im2col, grouped, H≠W)",
            1301,
            48,
            gen_conv,
            shrink_conv,
            |c| {
                let mut rng = Rng::new(c.seed);
                let kern = GroupedConv::randn(c.c_out, c.c_in, c.k, c.groups, 1.0, &mut rng);
                let x = Mat::randn(c.c_in * c.h * c.w, c.t, 1.0, &mut rng);
                let want = gemm_naive(&kern.to_dense().to_matrix(c.h, c.w), &x);
                for ctx in [direct_ctx(), im2col_ctx(), KernelCtx::default()] {
                    let got = conv_apply(&kern, &x, c.h, c.w, &ctx);
                    assert!(
                        got.fro_dist(&want) < 1e-9,
                        "plan {:?} diverged",
                        ctx.plan_conv(c.c_out, c.c_in / c.groups, c.k, c.h * c.w, c.t)
                    );
                }
            },
        );
    }

    #[test]
    fn grouped_apply_matches_dense_grouped_kernel() {
        prop::check_shrunk(
            "grouped conv == dense kernel with cross-group taps zeroed",
            1302,
            32,
            gen_conv,
            shrink_conv,
            |c| {
                let mut rng = Rng::new(c.seed);
                // Round-trip: a dense kernel, grouped-projected two ways.
                let dense = ConvKernel::randn(c.c_out, c.c_in, c.k, 1.0, &mut rng);
                let grouped = GroupedConv::from_dense(&dense, c.groups);
                let x: Vec<f64> = (0..c.c_in * c.h * c.w).map(|_| rng.normal()).collect();
                let want = dense.grouped(c.groups).conv(&x, c.h, c.w);
                let xm = Mat::from_rows(x.len(), 1, &x);
                let got = conv_apply(&grouped, &xm, c.h, c.w, &direct_ctx());
                for (i, &v) in want.iter().enumerate() {
                    assert!((got[(i, 0)] - v).abs() < 1e-10);
                }
            },
        );
    }

    #[derive(Debug, Clone, Copy)]
    struct ExpCase {
        c: usize,
        k: usize,
        groups: usize,
        h: usize,
        w: usize,
        terms: usize,
        seed: u64,
    }

    fn shrink_exp(c: &ExpCase) -> Vec<ExpCase> {
        let mut out = Vec::new();
        for f in prop::shrink_usize(c.c / c.groups, 1) {
            out.push(ExpCase { c: f * c.groups, ..*c });
        }
        for h in prop::shrink_usize(c.h, 1) {
            out.push(ExpCase { h, ..*c });
        }
        for w in prop::shrink_usize(c.w, 1) {
            out.push(ExpCase { w, ..*c });
        }
        for terms in prop::shrink_usize(c.terms, 1) {
            out.push(ExpCase { terms, ..*c });
        }
        out
    }

    #[test]
    fn streaming_conv_exp_matches_truncated_dense_series() {
        // Same truncation on both sides ⇒ agreement to rounding, for any
        // kernel magnitude (no convergence assumption needed).
        prop::check_shrunk(
            "conv_exp_apply == Σ Mⁿ/n! · vec(X)",
            1303,
            32,
            |rng| {
                let groups = prop::size_in(rng, 1, 2);
                ExpCase {
                    c: groups * prop::size_in(rng, 1, 3),
                    k: 3,
                    groups,
                    h: prop::size_in(rng, 1, 3),
                    w: prop::size_in(rng, 1, 4),
                    terms: prop::size_in(rng, 1, 6),
                    seed: rng.next_u64(),
                }
            },
            shrink_exp,
            |c| {
                let mut rng = Rng::new(c.seed);
                let kern = GroupedConv::randn(c.c, c.c, c.k, c.groups, 0.5, &mut rng);
                let d = c.c * c.h * c.w;
                let x = Mat::randn(d, 2, 1.0, &mut rng);
                let m = kern.to_dense().to_matrix(c.h, c.w);
                let mut acc = Mat::eye(d);
                let mut term = Mat::eye(d);
                for n in 1..=c.terms {
                    term = gemm_naive(&m, &term).scale(1.0 / n as f64);
                    acc = &acc + &term;
                }
                let want = gemm_naive(&acc, &x);
                for ctx in [direct_ctx(), im2col_ctx()] {
                    let got = conv_exp_apply(&kern, &x, c.h, c.w, c.terms, &ctx);
                    assert!(got.fro_dist(&want) < 1e-8 * (1.0 + want.fro_norm()));
                }
            },
        );
    }

    #[test]
    fn channel_shuffle_matches_perm_on_vec() {
        // Fast path == the dense channel_shuffle_perm on vec(X), at
        // rectangular H≠W sizes.
        prop::check_shrunk(
            "channel_shuffle_apply == P_shuffle · X (H≠W)",
            1304,
            48,
            |rng| {
                let c = prop::size_in(rng, 1, 6);
                (
                    c,
                    prop::size_in(rng, 1, 4),
                    prop::size_in(rng, 1, 5),
                    prop::size_in(rng, 1, 3),
                    rng.next_u64(),
                )
            },
            |&(c, h, w, t, seed)| {
                let mut out = Vec::new();
                for cc in prop::shrink_usize(c, 1) {
                    out.push((cc, h, w, t, seed));
                }
                for hh in prop::shrink_usize(h, 1) {
                    out.push((c, hh, w, t, seed));
                }
                for ww in prop::shrink_usize(w, 1) {
                    out.push((c, h, ww, t, seed));
                }
                out
            },
            |&(c, h, w, t, seed)| {
                let mut rng = Rng::new(seed);
                let chperm = Perm::random(c, &mut rng);
                let x = Mat::randn(c * h * w, t, 1.0, &mut rng);
                let got = channel_shuffle_apply(&chperm, &x, h * w);
                let want = crate::gs::conv::channel_shuffle_perm(&chperm, h, w).apply_rows(&x);
                assert!(got.fro_dist(&want) < 1e-15);
            },
        );
    }

    #[test]
    fn gs_soc_layer_matches_its_dense_matrix() {
        prop::check_named("GsSocLayer apply == to_matrix · X", 1305, 24, |rng| {
            let groups = prop::size_in(rng, 1, 2);
            let c = groups * 2 * prop::size_in(rng, 1, 2);
            let (h, w) = (prop::size_in(rng, 1, 3), prop::size_in(rng, 1, 3));
            let layer = GsSocLayer::random(c, 3, groups, h, w, prop::size_in(rng, 1, 5), 0.4, rng);
            let x = Mat::randn(layer.d(), 2, 1.0, rng);
            let want = gemm_naive(&layer.to_matrix(), &x);
            for ctx in [direct_ctx(), im2col_ctx()] {
                assert!(layer.apply(&x, &ctx).fro_dist(&want) < 1e-9 * (1.0 + want.fro_norm()));
            }
        });
    }

    #[test]
    fn transposed_layer_is_the_exact_adjoint() {
        prop::check_named("⟨Lx, y⟩ == ⟨x, Lᵀy⟩ for GS-SOC layers", 1306, 24, |rng| {
            let groups = prop::size_in(rng, 1, 2);
            let c = groups * prop::size_in(rng, 1, 3);
            let (h, w) = (prop::size_in(rng, 1, 3), prop::size_in(rng, 2, 4));
            let layer = GsSocLayer::random(c, 3, groups, h, w, 4, 0.6, rng);
            let ctx = KernelCtx::default();
            let x = Mat::randn(layer.d(), 1, 1.0, rng);
            let y = Mat::randn(layer.d(), 1, 1.0, rng);
            let lx = layer.apply(&x, &ctx);
            let lty = layer.transposed().apply(&y, &ctx);
            let lhs: f64 = lx.data.iter().zip(y.data.iter()).map(|(a, b)| a * b).sum();
            let rhs: f64 = x.data.iter().zip(lty.data.iter()).map(|(a, b)| a * b).sum();
            assert!(
                (lhs - rhs).abs() < 1e-9 * (1.0 + lhs.abs().max(rhs.abs())),
                "{lhs} vs {rhs}"
            );
        });
    }

    #[test]
    fn gs_soc_jacobian_is_orthogonal_at_converged_truncation() {
        // Small kernel norm + enough terms ⇒ the truncated exponential of
        // the skew Eq. 2 matrix is orthogonal to certifier tolerance.
        let mut rng = Rng::new(9);
        let layer = GsSocLayer::random(8, 3, 2, 3, 4, 18, 0.05, &mut rng);
        let j = layer.to_matrix();
        assert!(j.is_orthogonal(1e-8), "err={}", j.orthogonality_error());
    }

    #[test]
    fn conv_transpose_matches_dense_transpose() {
        prop::check("grouped conv_transpose == Eq2 matrix transpose", 1307, |rng| {
            let groups = prop::size_in(rng, 1, 2);
            let kern = GroupedConv::randn(
                groups * prop::size_in(rng, 1, 2),
                groups * prop::size_in(rng, 1, 2),
                3,
                groups,
                1.0,
                rng,
            );
            let (h, w) = (2, 3);
            let mt = kern.conv_transpose().to_dense().to_matrix(h, w);
            assert!(mt.fro_dist(&kern.to_dense().to_matrix(h, w).t()) < 1e-12);
        });
    }

    #[test]
    fn nchw_batch_equals_per_image_convolution() {
        prop::check("conv_apply_nchw == per-image conv", 1308, |rng| {
            let groups = prop::size_in(rng, 1, 2);
            let kern = GroupedConv::randn(
                groups * prop::size_in(rng, 1, 2),
                groups * prop::size_in(rng, 1, 2),
                3,
                groups,
                1.0,
                rng,
            );
            let (h, w) = (prop::size_in(rng, 1, 3), prop::size_in(rng, 1, 4));
            let n = prop::size_in(rng, 1, 3);
            let d_in = kern.c_in * h * w;
            let d_out = kern.c_out * h * w;
            let x: Vec<f64> = (0..n * d_in).map(|_| rng.normal()).collect();
            let ctx = KernelCtx::default();
            let batched = conv_apply_nchw(&kern, &x, n, h, w, &ctx);
            assert_eq!(batched.len(), n * d_out);
            for j in 0..n {
                let single = conv_image(&kern, &x[j * d_in..(j + 1) * d_in], h, w, &ctx);
                for (a, b) in batched[j * d_out..(j + 1) * d_out].iter().zip(single.iter()) {
                    assert!((a - b).abs() < 1e-12);
                }
            }
        });
    }

    #[test]
    #[should_panic(expected = "conv apply shape mismatch")]
    fn conv_apply_shape_mismatch_is_a_hard_assert() {
        let kern = GroupedConv::zeros(2, 2, 3, 1);
        conv_apply(&kern, &Mat::zeros(7, 1), 2, 2, &KernelCtx::default());
    }

    #[test]
    #[should_panic(expected = "channel shuffle shape mismatch")]
    fn shuffle_shape_mismatch_is_a_hard_assert() {
        let p = Perm::identity(3);
        channel_shuffle_apply(&p, &Mat::zeros(10, 1), 4);
    }
}
