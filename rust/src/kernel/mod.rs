//! Fused group-and-shuffle CPU kernel subsystem — the pure-Rust mirror of
//! the Pallas L1 kernels (`python/compile/kernels/gs_kernels.py`), fronted
//! by the existing `Mat`/`gs` method surface so every hot path in the
//! crate (the serving engine's cached-dense, cold-merge and factorized
//! paths, the GS algebra, the experiment harnesses) runs through it:
//!
//! - [`gemm`] — cache-blocked, register-tiled dense GEMM with a parallel
//!   row-panel driver on the persistent worker pool, plus the naive
//!   reference loop ([`gemm_naive`]) and an unrolled [`gemv`]
//! - [`fused`] — the fused group-and-shuffle kernel: block-diagonal GEMM
//!   with the `P_(k,n)` relayouts folded in as gathers/scatters
//!   ([`fused_apply`]), two-pass [`gs_apply`], per-stage [`chain_apply`],
//!   batched multi-RHS variants, and the permutation relayouts
//! - [`conv`] — the direct GS-SOC orthogonal-convolution runtime:
//!   same-padded grouped conv (direct AXPY loop / im2col-into-blocked-GEMM
//!   chosen by [`KernelCtx::plan_conv`]), the streaming convolution
//!   exponential, channel-shuffle plane relayouts, and the one-pass
//!   [`GsSocLayer`] (`P_out · exp(grouped skew conv) · P_in`)
//! - [`convbench`] — the `gsoft conv-bench` sweep (deterministic record
//!   builder, reused by the integration determinism test)
//! - [`dispatch`] — [`KernelCtx`]: per-shape naive/blocked/parallel
//!   dispatch, tile autotuning, and the process-wide default [`ctx`]
//!
//! Rust kernel ↔ Pallas L1 counterpart (see DESIGN.md §Perf):
//! `fused_apply` ↔ `shuffled_block_diag_matmul`; `fused_apply(…, None,
//! None, …)` ↔ `block_diag_matmul`; `gs_apply` ↔ the L1 `gs_apply`;
//! `gemm_blocked` ↔ `bmm`; `KernelCtx` tiles ↔ `vmem_footprint_bytes`.
//!
//! Benchmarked by `gsoft kernel-bench` (writes `BENCH_kernels.json`) and
//! `rust/benches/kernels.rs`; every path is property-tested equal to the
//! dense `to_dense().matmul(..)` reference, including non-divisible edge
//! tiles.

pub mod conv;
pub mod convbench;
pub mod dispatch;
pub mod fused;
pub mod gemm;

pub use conv::{
    channel_shuffle_apply, conv_apply, conv_apply_nchw, conv_exp_apply, conv_image, GroupedConv,
    GsSocLayer,
};
pub use dispatch::{ctx, ConvKind, GemmKind, KernelCtx};
pub use fused::{
    chain_apply, chain_apply_batch, fused_apply, gs_apply, gs_apply_batch, permute_cols,
    permute_rows, FusedPlan, GsOp,
};
pub use gemm::{gemm_blocked, gemm_naive, gemv, Tile};
