//! Cache-blocked, register-tiled f64 GEMM — the dense workhorse behind
//! `Mat::matmul` (the Rust mirror of the dense baseline the Pallas L1
//! kernels are measured against).
//!
//! Layout: classic three-level blocking. The innermost micro-kernel keeps
//! an `MR×NR` accumulator block in locals; around it, panels of `B` are
//! packed contiguously per `(kc, nc)` tile so the micro-kernel streams
//! unit-stride; the outer loops walk `(nc, kc, mc)` cache tiles. Edge
//! tiles (dimensions not divisible by any tile size) are handled by
//! clamping every tile to the remaining extent — property-tested against
//! [`gemm_naive`] across non-divisible shapes.
//!
//! The parallel driver splits `A`'s rows into contiguous panels across the
//! persistent worker pool ([`crate::util::pool::parallel_map`]); panels
//! are disjoint, so results concatenate without synchronization.

use crate::linalg::Mat;
use crate::util::pool::parallel_map;

/// Register micro-tile rows (accumulator block height).
pub const MR: usize = 4;
/// Register micro-tile cols (accumulator block width).
pub const NR: usize = 4;

/// Cache tile sizes: `mc` rows of `A`, `kc` inner depth, `nc` cols of `B`
/// per packed panel. Defaults target ~L1-resident packed panels for f64.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tile {
    pub mc: usize,
    pub kc: usize,
    pub nc: usize,
}

impl Default for Tile {
    fn default() -> Tile {
        Tile {
            mc: 64,
            kc: 64,
            nc: 256,
        }
    }
}

/// Reference GEMM: the original `Mat::matmul` ikj loop, kept verbatim as
/// the property-test oracle and the dispatch choice for small shapes
/// (where tiling overhead outweighs cache wins). The zero-skip makes it
/// cheap on permutation-like operands.
pub fn gemm_naive(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(
        a.cols, b.rows,
        "matmul shape mismatch: {}x{} @ {}x{}",
        a.rows, a.cols, b.rows, b.cols
    );
    let mut out = Mat::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for k in 0..a.cols {
            let av = a[(i, k)];
            if av == 0.0 {
                continue;
            }
            let brow = &b.data[k * b.cols..(k + 1) * b.cols];
            let orow = &mut out.data[i * b.cols..(i + 1) * b.cols];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
    out
}

/// Pack all of `B` tile-wise: each `(kc, nc)` cache tile contiguous with
/// row stride `ncc`, so the micro-kernel streams unit-stride and parallel
/// row strips share one read-only pack instead of re-packing per strip.
/// Tile `(jc, kc)` starts at offset `kdim·jc + kc·ncc` (panel widths sum
/// telescopically), so lookups are O(1); the pack is exactly one extra
/// copy of `B`.
fn pack_b(b: &Mat, tile: Tile) -> Vec<f64> {
    let n = b.cols;
    let kdim = b.rows;
    let mut pack = vec![0.0; kdim * n];
    let mut jc = 0;
    while jc < n {
        let ncc = tile.nc.min(n - jc);
        let mut kc = 0;
        while kc < kdim {
            let kcc = tile.kc.min(kdim - kc);
            let base = kdim * jc + kc * ncc;
            for k in 0..kcc {
                let src = &b.data[(kc + k) * n + jc..(kc + k) * n + jc + ncc];
                pack[base + k * ncc..base + (k + 1) * ncc].copy_from_slice(src);
            }
            kc += kcc;
        }
        jc += ncc;
    }
    pack
}

/// Blocked GEMM over the row range `r0..r1` of `A` against a shared
/// [`pack_b`] layout of `B` (`n = B.cols`), producing that strip of the
/// output row-major.
fn gemm_strip(a: &Mat, bpack: &[f64], n: usize, r0: usize, r1: usize, tile: Tile) -> Vec<f64> {
    let kdim = a.cols;
    let mut out = vec![0.0; (r1 - r0) * n];
    let mut jc = 0;
    while jc < n {
        let ncc = tile.nc.min(n - jc);
        let mut kc = 0;
        while kc < kdim {
            let kcc = tile.kc.min(kdim - kc);
            let btile = &bpack[kdim * jc + kc * ncc..kdim * jc + kc * ncc + kcc * ncc];
            let mut ic = r0;
            while ic < r1 {
                let mcc = tile.mc.min(r1 - ic);
                let mut ir = 0;
                while ir < mcc {
                    let mr = MR.min(mcc - ir);
                    let mut jr = 0;
                    while jr < ncc {
                        let nr = NR.min(ncc - jr);
                        let mut acc = [[0.0f64; NR]; MR];
                        for k in 0..kcc {
                            let brow = &btile[k * ncc + jr..k * ncc + jr + nr];
                            for (i, accrow) in acc.iter_mut().enumerate().take(mr) {
                                let av = a.data[(ic + ir + i) * kdim + kc + k];
                                for (av_acc, &bv) in accrow.iter_mut().zip(brow.iter()) {
                                    *av_acc += av * bv;
                                }
                            }
                        }
                        for (i, accrow) in acc.iter().enumerate().take(mr) {
                            let base = (ic + ir + i - r0) * n + jc + jr;
                            let orow = &mut out[base..base + nr];
                            for (o, &v) in orow.iter_mut().zip(accrow.iter()) {
                                *o += v;
                            }
                        }
                        jr += nr;
                    }
                    ir += mr;
                }
                ic += mcc;
            }
            kc += kcc;
        }
        jc += ncc;
    }
    out
}

/// Cache-blocked GEMM; with `workers > 1`, row panels are computed in
/// parallel on the persistent pool.
pub fn gemm_blocked(a: &Mat, b: &Mat, tile: Tile, workers: usize) -> Mat {
    assert_eq!(
        a.cols, b.rows,
        "matmul shape mismatch: {}x{} @ {}x{}",
        a.rows, a.cols, b.rows, b.cols
    );
    let m = a.rows;
    let n = b.cols;
    let bpack = pack_b(b, tile);
    let strips = workers.clamp(1, m.max(1));
    if strips == 1 {
        return Mat {
            rows: m,
            cols: n,
            data: gemm_strip(a, &bpack, n, 0, m, tile),
        };
    }
    let bounds: Vec<(usize, usize)> = (0..strips)
        .map(|s| (m * s / strips, m * (s + 1) / strips))
        .collect();
    let parts = parallel_map(strips, strips, |s| {
        gemm_strip(a, &bpack, n, bounds[s].0, bounds[s].1, tile)
    });
    let mut data = Vec::with_capacity(m * b.cols);
    for p in &parts {
        data.extend_from_slice(p);
    }
    Mat {
        rows: m,
        cols: b.cols,
        data,
    }
}

/// Matrix-vector product with a 4-way unrolled dot (breaks the serial
/// FP-add dependency chain); with `workers > 1`, row chunks run on the
/// persistent pool.
pub fn gemv(a: &Mat, x: &[f64], workers: usize) -> Vec<f64> {
    assert_eq!(
        a.cols,
        x.len(),
        "matvec shape mismatch: {}x{} @ {}-vector",
        a.rows,
        a.cols,
        x.len()
    );
    let dot = |i: usize| -> f64 {
        let row = a.row(i);
        let mut acc = [0.0f64; 4];
        let quads = row.len() / 4 * 4;
        let mut k = 0;
        while k < quads {
            acc[0] += row[k] * x[k];
            acc[1] += row[k + 1] * x[k + 1];
            acc[2] += row[k + 2] * x[k + 2];
            acc[3] += row[k + 3] * x[k + 3];
            k += 4;
        }
        let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
        while k < row.len() {
            s += row[k] * x[k];
            k += 1;
        }
        s
    };
    let chunks = workers.clamp(1, a.rows.max(1));
    if chunks == 1 {
        return (0..a.rows).map(dot).collect();
    }
    let bounds: Vec<(usize, usize)> = (0..chunks)
        .map(|c| (a.rows * c / chunks, a.rows * (c + 1) / chunks))
        .collect();
    let parts = parallel_map(chunks, chunks, |c| {
        (bounds[c].0..bounds[c].1).map(dot).collect::<Vec<f64>>()
    });
    let mut y = Vec::with_capacity(a.rows);
    for p in &parts {
        y.extend_from_slice(p);
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[derive(Debug, Clone, Copy)]
    struct GemmCase {
        m: usize,
        k: usize,
        n: usize,
        seed: u64,
    }

    fn shrink_case(c: &GemmCase) -> Vec<GemmCase> {
        let mut out = Vec::new();
        for m in prop::shrink_usize(c.m, 1) {
            out.push(GemmCase { m, ..*c });
        }
        for k in prop::shrink_usize(c.k, 1) {
            out.push(GemmCase { k, ..*c });
        }
        for n in prop::shrink_usize(c.n, 1) {
            out.push(GemmCase { n, ..*c });
        }
        out
    }

    #[test]
    fn blocked_gemm_matches_naive_including_edge_tiles() {
        // Tiny tiles against dims up to 40 force partial tiles at every
        // boundary, and dims are not multiples of MR/NR either.
        let tile = Tile { mc: 5, kc: 3, nc: 7 };
        prop::check_shrunk(
            "blocked gemm == naive gemm",
            1101,
            48,
            |rng| GemmCase {
                m: prop::size_in(rng, 1, 40),
                k: prop::size_in(rng, 1, 40),
                n: prop::size_in(rng, 1, 40),
                seed: rng.next_u64(),
            },
            shrink_case,
            |c| {
                let mut rng = Rng::new(c.seed);
                let a = Mat::randn(c.m, c.k, 1.0, &mut rng);
                let b = Mat::randn(c.k, c.n, 1.0, &mut rng);
                let want = gemm_naive(&a, &b);
                let single = gemm_blocked(&a, &b, tile, 1);
                assert!(single.fro_dist(&want) < 1e-9, "single-thread blocked");
                let multi = gemm_blocked(&a, &b, tile, 3);
                assert!(multi.fro_dist(&want) < 1e-9, "parallel row panels");
            },
        );
    }

    #[test]
    fn default_tile_matches_naive_on_larger_shapes() {
        let mut rng = Rng::new(9);
        let a = Mat::randn(70, 130, 1.0, &mut rng);
        let b = Mat::randn(130, 50, 1.0, &mut rng);
        let want = gemm_naive(&a, &b);
        assert!(gemm_blocked(&a, &b, Tile::default(), 1).fro_dist(&want) < 1e-9);
        assert!(gemm_blocked(&a, &b, Tile::default(), 4).fro_dist(&want) < 1e-9);
    }

    #[test]
    fn gemv_matches_reference_serial_and_parallel() {
        prop::check("gemv == row dot products", 1102, |rng| {
            let m = prop::size_in(rng, 1, 30);
            let n = prop::size_in(rng, 1, 30);
            let a = Mat::randn(m, n, 1.0, rng);
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let want: Vec<f64> = (0..m)
                .map(|i| a.row(i).iter().zip(x.iter()).map(|(p, q)| p * q).sum())
                .collect();
            for workers in [1, 3] {
                let got = gemv(&a, &x, workers);
                for (u, v) in got.iter().zip(want.iter()) {
                    assert!((u - v).abs() < 1e-9, "workers={workers}: {u} vs {v}");
                }
            }
        });
    }

    #[test]
    #[should_panic(expected = "matmul shape mismatch")]
    fn gemm_shape_mismatch_is_a_hard_assert() {
        // A real assert!, not debug_assert!: must fire in release builds
        // too (the tier-1 gate builds --release).
        gemm_naive(&Mat::zeros(2, 3), &Mat::zeros(4, 2));
    }

    #[test]
    #[should_panic(expected = "matvec shape mismatch")]
    fn gemv_shape_mismatch_is_a_hard_assert() {
        gemv(&Mat::zeros(2, 3), &[0.0; 4], 1);
    }

    #[test]
    fn degenerate_dimensions() {
        // Zero inner dimension: the product is the zero matrix.
        let a = Mat::zeros(3, 0);
        let b = Mat::zeros(0, 2);
        let c = gemm_blocked(&a, &b, Tile::default(), 2);
        assert_eq!((c.rows, c.cols), (3, 2));
        assert!(c.data.iter().all(|&v| v == 0.0));
        // Zero output columns.
        let c = gemm_blocked(&Mat::zeros(2, 3), &Mat::zeros(3, 0), Tile::default(), 1);
        assert_eq!((c.rows, c.cols), (2, 0));
    }
}
