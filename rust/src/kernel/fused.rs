//! Fused group-and-shuffle kernels: apply a block-diagonal factor
//! ("group") and the `P_(k,n)` relayouts ("shuffle") in one pass, without
//! materializing any intermediate matrix — the pure-Rust mirror of the
//! Pallas L1 `shuffled_block_diag_matmul` kernel
//! (`python/compile/kernels/gs_kernels.py`).
//!
//! [`fused_apply`] computes `P_out · (B · (P_in · X))` in a single sweep:
//! the input shuffle becomes a row *gather* (through the inverse
//! permutation) and the output shuffle a row *scatter*, both folded into
//! the per-block GEMM loop. A two-factor [`crate::gs::GsMatrix`] apply is
//! two fused passes instead of five ([`gs_apply`]); an `m`-factor
//! [`crate::gs::GsChain`] is `m` passes instead of `2m+1`
//! ([`chain_apply`]). This is what makes the Theorem-2 `O(m·nnz)` cost
//! real on CPU: per column, `m·d·b` multiply-adds and zero relayout
//! traffic.
//!
//! For multi-block factors the arithmetic order per output row is
//! identical to the unfused `Perm::apply_rows` → `BlockDiag::matmul_right`
//! pipeline, so those results are bitwise-equal to the pre-kernel
//! implementation; the one exception is a single relayout-free block,
//! which dispatches to the cache-blocked GEMM above the naive threshold
//! and agrees only to rounding (1e-9 in the property tests).

use crate::gs::{BlockDiag, GsChain, GsMatrix, Perm};
use crate::linalg::Mat;
use crate::util::pool::parallel_map;

use super::dispatch::KernelCtx;

/// Skip the gather/scatter indirection for identity relayouts.
fn nonidentity(p: &Perm) -> Option<&Perm> {
    if p.is_identity() {
        None
    } else {
        Some(p)
    }
}

/// `P · A` — permute rows (row `i` of `A` lands at row `σ(i)`); one
/// row-copy pass.
pub fn permute_rows(p: &Perm, a: &Mat) -> Mat {
    assert_eq!(
        a.rows,
        p.n(),
        "P·A shape mismatch: P is {}x{}, A is {}x{}",
        p.n(),
        p.n(),
        a.rows,
        a.cols
    );
    let mut out = Mat::zeros(a.rows, a.cols);
    for (i, &dst) in p.sigma.iter().enumerate() {
        out.data[dst * a.cols..(dst + 1) * a.cols].copy_from_slice(a.row(i));
    }
    out
}

/// `A · P` — permute columns (column `σ(j)` of `A` lands at column `j`);
/// one gather pass per row over contiguous slices.
pub fn permute_cols(p: &Perm, a: &Mat) -> Mat {
    assert_eq!(
        a.cols,
        p.n(),
        "A·P shape mismatch: A is {}x{}, P is {}x{}",
        a.rows,
        a.cols,
        p.n(),
        p.n()
    );
    let mut out = Mat::zeros(a.rows, a.cols);
    for i in 0..a.rows {
        let src = a.row(i);
        let dst = &mut out.data[i * a.cols..(i + 1) * a.cols];
        for (d, &s) in dst.iter_mut().zip(p.sigma.iter()) {
            *d = src[s];
        }
    }
    out
}

/// One fused pass `P_out · (B · (P_in · X))`. `None` relayouts skip their
/// indirection entirely (so `fused_apply(bd, None, None, x, ctx)` is a
/// bare block-diagonal GEMM). Large applies fan blocks out across the
/// persistent pool — block output rows are disjoint even after the
/// scatter, because `σ` is a bijection.
pub fn fused_apply(
    bd: &BlockDiag,
    p_in: Option<&Perm>,
    p_out: Option<&Perm>,
    x: &Mat,
    ctx: &KernelCtx,
) -> Mat {
    assert_eq!(
        bd.cols(),
        x.rows,
        "fused apply shape mismatch: blockdiag {}x{} @ {}x{}",
        bd.rows(),
        bd.cols(),
        x.rows,
        x.cols
    );
    if let Some(p) = p_in {
        assert_eq!(
            p.n(),
            x.rows,
            "fused apply: P_in is {}x{} but X has {} rows",
            p.n(),
            p.n(),
            x.rows
        );
    }
    if let Some(p) = p_out {
        assert_eq!(
            p.n(),
            bd.rows(),
            "fused apply: P_out is {}x{} but the blockdiag has {} rows",
            p.n(),
            p.n(),
            bd.rows()
        );
    }
    // Input shuffle as a gather: (P_in X) row j = X row σ⁻¹(j).
    let gather = p_in.map(|p| p.inverse().sigma);
    let offsets = block_offsets(bd);
    fused_run(
        bd,
        gather.as_deref(),
        p_out.map(|p| p.sigma.as_slice()),
        &offsets,
        x,
        ctx,
    )
}

/// Row/col offsets of each block inside the block-diagonal frame.
fn block_offsets(bd: &BlockDiag) -> Vec<(usize, usize)> {
    let mut offsets = Vec::with_capacity(bd.blocks.len());
    let (mut r0, mut c0) = (0, 0);
    for blk in &bd.blocks {
        offsets.push((r0, c0));
        r0 += blk.rows;
        c0 += blk.cols;
    }
    offsets
}

/// The fused sweep itself, over pre-resolved gather/scatter maps and
/// block offsets (one-shot callers resolve them in [`fused_apply`];
/// repeated callers keep them in a [`FusedPlan`]).
fn fused_run(
    bd: &BlockDiag,
    gather: Option<&[usize]>,
    scatter: Option<&[usize]>,
    offsets: &[(usize, usize)],
    x: &Mat,
    ctx: &KernelCtx,
) -> Mat {
    // A single relayout-free block is just a dense product — hand it to
    // the GEMM dispatcher so coarse-blocked operands (e.g. OFT with
    // block == d) still get cache blocking and row-panel parallelism.
    if bd.blocks.len() == 1 && gather.is_none() && scatter.is_none() {
        return ctx.gemm(&bd.blocks[0], x);
    }
    let t = x.cols;
    let mut out = Mat::zeros(bd.rows(), t);

    let workers = ctx.fused_workers(bd, t);
    if workers > 1 && bd.blocks.len() > 1 {
        // Per-block strips computed in parallel, scattered afterwards.
        let strips = parallel_map(bd.blocks.len(), workers, |bi| {
            let blk = &bd.blocks[bi];
            let c0 = offsets[bi].1;
            let mut strip = vec![0.0; blk.rows * t];
            for i in 0..blk.rows {
                let orow = &mut strip[i * t..(i + 1) * t];
                accumulate_row(blk, i, c0, gather, x, orow);
            }
            strip
        });
        for (bi, strip) in strips.iter().enumerate() {
            let r0 = offsets[bi].0;
            for i in 0..bd.blocks[bi].rows {
                let dst = match scatter {
                    Some(s) => s[r0 + i],
                    None => r0 + i,
                };
                out.data[dst * t..(dst + 1) * t].copy_from_slice(&strip[i * t..(i + 1) * t]);
            }
        }
    } else {
        // Serial: write each output row straight to its scattered
        // destination (each destination row is owned by exactly one
        // (block, row) pair).
        for (bi, blk) in bd.blocks.iter().enumerate() {
            let (r0, c0) = offsets[bi];
            for i in 0..blk.rows {
                let dst = match scatter {
                    Some(s) => s[r0 + i],
                    None => r0 + i,
                };
                let orow = &mut out.data[dst * t..(dst + 1) * t];
                accumulate_row(blk, i, c0, gather, x, orow);
            }
        }
    }
    out
}

/// Accumulate one block-row product `Σ_k B[i,k] · X[gather(c0+k)]` into
/// `orow` (the innermost fused loop, shared by the serial and parallel
/// drivers).
#[inline]
fn accumulate_row(
    blk: &Mat,
    i: usize,
    c0: usize,
    gather: Option<&[usize]>,
    x: &Mat,
    orow: &mut [f64],
) {
    for k in 0..blk.cols {
        let f = blk[(i, k)];
        if f == 0.0 {
            continue;
        }
        let src = match gather {
            Some(inv) => inv[c0 + k],
            None => c0 + k,
        };
        for (o, &v) in orow.iter_mut().zip(x.row(src).iter()) {
            *o += f * v;
        }
    }
}

/// Precomputed relayout maps + block offsets for one fused pass —
/// resolved once per operator instead of per apply. Pair it only with the
/// block-diagonal factor it was planned for.
pub struct FusedPlan {
    gather: Option<Vec<usize>>,
    scatter: Option<Vec<usize>>,
    offsets: Vec<(usize, usize)>,
}

impl FusedPlan {
    pub fn new(bd: &BlockDiag, p_in: Option<&Perm>, p_out: Option<&Perm>) -> FusedPlan {
        if let Some(p) = p_in {
            assert_eq!(
                p.n(),
                bd.cols(),
                "fused plan: P_in size {} must match blockdiag cols {}",
                p.n(),
                bd.cols()
            );
        }
        if let Some(p) = p_out {
            assert_eq!(
                p.n(),
                bd.rows(),
                "fused plan: P_out size {} must match blockdiag rows {}",
                p.n(),
                bd.rows()
            );
        }
        FusedPlan {
            gather: p_in.map(|p| p.inverse().sigma),
            scatter: p_out.map(|p| p.sigma.clone()),
            offsets: block_offsets(bd),
        }
    }

    /// Run the planned pass against its block-diagonal factor.
    pub fn apply(&self, bd: &BlockDiag, x: &Mat, ctx: &KernelCtx) -> Mat {
        assert_eq!(
            self.offsets.len(),
            bd.blocks.len(),
            "fused plan was built for a different blockdiag"
        );
        assert_eq!(
            bd.cols(),
            x.rows,
            "fused apply shape mismatch: blockdiag {}x{} @ {}x{}",
            bd.rows(),
            bd.cols(),
            x.rows,
            x.cols
        );
        fused_run(
            bd,
            self.gather.as_deref(),
            self.scatter.as_deref(),
            &self.offsets,
            x,
            ctx,
        )
    }
}

/// A prepared two-pass GS operator: owns the factors plus the
/// precomputed relayout plans, so repeated applies — the serving engine's
/// factorized hot path, which reuses one operator per tenant layer across
/// every batch — pay zero per-call planning cost.
pub struct GsOp {
    gs: GsMatrix,
    pass_r: FusedPlan,
    pass_l: FusedPlan,
}

impl GsOp {
    pub fn new(gs: GsMatrix) -> GsOp {
        let pass_r = FusedPlan::new(&gs.r, nonidentity(&gs.spec.p_r), nonidentity(&gs.spec.p));
        let pass_l = FusedPlan::new(&gs.l, None, nonidentity(&gs.spec.p_l));
        GsOp { gs, pass_r, pass_l }
    }

    /// `A · X` via the two planned fused passes (same result as
    /// [`gs_apply`]).
    pub fn apply(&self, x: &Mat, ctx: &KernelCtx) -> Mat {
        assert_eq!(
            x.rows,
            self.gs.spec.n(),
            "GS op: X has {} rows, spec expects {}",
            x.rows,
            self.gs.spec.n()
        );
        let mid = self.pass_r.apply(&self.gs.r, x, ctx);
        self.pass_l.apply(&self.gs.l, &mid, ctx)
    }
}

/// Two-factor GS apply `A·X = P_L (L (P (R (P_R X))))` as two fused
/// passes: the first folds `P_R` (gather) and `P` (scatter) around the
/// `R` grouped GEMM, the second folds `P_L` (scatter) around `L`.
pub fn gs_apply(gs: &GsMatrix, x: &Mat, ctx: &KernelCtx) -> Mat {
    assert_eq!(
        x.rows,
        gs.spec.n(),
        "GS apply: X has {} rows, spec expects {}",
        x.rows,
        gs.spec.n()
    );
    let mid = fused_apply(
        &gs.r,
        nonidentity(&gs.spec.p_r),
        nonidentity(&gs.spec.p),
        x,
        ctx,
    );
    fused_apply(&gs.l, None, nonidentity(&gs.spec.p_l), &mid, ctx)
}

/// Higher-order chain apply `P_out (B_m P_m) ⋯ (B_1 P_1) X` as `m` fused
/// passes: each stage gathers through its own `P_i`, and the final
/// `P_out` relayout rides the last stage's scatter.
pub fn chain_apply(chain: &GsChain, x: &Mat, ctx: &KernelCtx) -> Mat {
    assert_eq!(
        x.rows,
        chain.n(),
        "chain apply: X has {} rows, chain expects {}",
        x.rows,
        chain.n()
    );
    let last = chain.stages.len() - 1;
    let mut cur: Option<Mat> = None;
    for (i, st) in chain.stages.iter().enumerate() {
        let p_out = if i == last {
            nonidentity(&chain.p_out)
        } else {
            None
        };
        let inp = cur.as_ref().unwrap_or(x);
        cur = Some(fused_apply(&st.block, nonidentity(&st.perm), p_out, inp, ctx));
    }
    cur.expect("GsChain has at least one stage")
}

/// Batched multi-RHS GS apply: one structured operator over many
/// right-hand sides, fanned out across the persistent pool (the serving
/// engine's cross-batch shape). Each RHS is applied with a serial inner
/// context so parallelism lives at the batch level.
pub fn gs_apply_batch(gs: &GsMatrix, xs: &[Mat], ctx: &KernelCtx) -> Vec<Mat> {
    let serial = KernelCtx { workers: 1, ..*ctx };
    parallel_map(xs.len(), ctx.workers, |i| gs_apply(gs, &xs[i], &serial))
}

/// Batched multi-RHS chain apply (see [`gs_apply_batch`]).
pub fn chain_apply_batch(chain: &GsChain, xs: &[Mat], ctx: &KernelCtx) -> Vec<Mat> {
    let serial = KernelCtx { workers: 1, ..*ctx };
    parallel_map(xs.len(), ctx.workers, |i| chain_apply(chain, &xs[i], &serial))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gs::GsSpec;
    use crate::kernel::gemm::gemm_naive;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn parallel_ctx() -> KernelCtx {
        // Forces the parallel fused driver regardless of shape.
        KernelCtx {
            parallel_above_flops: 0,
            workers: 3,
            ..KernelCtx::default()
        }
    }

    #[derive(Debug, Clone, Copy)]
    struct FusedCase {
        k: usize,
        br: usize,
        bc: usize,
        t: usize,
        seed: u64,
    }

    fn shrink_fused(c: &FusedCase) -> Vec<FusedCase> {
        let mut out = Vec::new();
        for k in prop::shrink_usize(c.k, 1) {
            out.push(FusedCase { k, ..*c });
        }
        for br in prop::shrink_usize(c.br, 1) {
            out.push(FusedCase { br, ..*c });
        }
        for bc in prop::shrink_usize(c.bc, 1) {
            out.push(FusedCase { bc, ..*c });
        }
        for t in prop::shrink_usize(c.t, 1) {
            out.push(FusedCase { t, ..*c });
        }
        out
    }

    #[test]
    fn fused_apply_matches_dense_reference() {
        // Oracle built purely from to_mat() + the naive GEMM — fully
        // independent of every kernel under test. Rectangular blocks
        // included.
        prop::check_shrunk(
            "fused group-and-shuffle == dense P_out·B·P_in·X",
            1201,
            48,
            |rng| FusedCase {
                k: prop::size_in(rng, 1, 5),
                br: prop::size_in(rng, 1, 5),
                bc: prop::size_in(rng, 1, 5),
                t: prop::size_in(rng, 1, 4),
                seed: rng.next_u64(),
            },
            shrink_fused,
            |c| {
                let mut rng = Rng::new(c.seed);
                let bd = BlockDiag::randn(c.k, c.br, c.bc, 1.0, &mut rng);
                let p_in = Perm::random(bd.cols(), &mut rng);
                let p_out = Perm::random(bd.rows(), &mut rng);
                let x = Mat::randn(bd.cols(), c.t, 1.0, &mut rng);
                let dense = gemm_naive(
                    &gemm_naive(&gemm_naive(&p_out.to_mat(), &bd.to_mat()), &p_in.to_mat()),
                    &x,
                );
                for ctx in [KernelCtx::default(), parallel_ctx()] {
                    let fused = fused_apply(&bd, Some(&p_in), Some(&p_out), &x, &ctx);
                    assert!(fused.fro_dist(&dense) < 1e-9, "both relayouts");
                    let bare = fused_apply(&bd, None, None, &x, &ctx);
                    assert!(
                        bare.fro_dist(&gemm_naive(&bd.to_mat(), &x)) < 1e-9,
                        "no relayouts"
                    );
                }
            },
        );
    }

    #[derive(Debug, Clone, Copy)]
    struct ChainCase {
        b: usize,
        r: usize,
        m: usize,
        t: usize,
        seed: u64,
    }

    fn shrink_chain(c: &ChainCase) -> Vec<ChainCase> {
        let mut out = Vec::new();
        for r in prop::shrink_usize(c.r, 2) {
            out.push(ChainCase { r, ..*c });
        }
        for m in prop::shrink_usize(c.m, 1) {
            out.push(ChainCase { m, ..*c });
        }
        for t in prop::shrink_usize(c.t, 1) {
            out.push(ChainCase { t, ..*c });
        }
        out
    }

    #[test]
    fn chain_apply_matches_factor_product_oracle() {
        // Dense oracle assembled factor-by-factor with the naive GEMM, so
        // this covers the fused path end-to-end across (r, b, m, batch).
        prop::check_shrunk(
            "fused chain apply == dense factor product",
            1202,
            32,
            |rng| ChainCase {
                b: [2usize, 3][rng.below(2)],
                r: prop::size_in(rng, 2, 4),
                m: prop::size_in(rng, 1, 3),
                t: prop::size_in(rng, 1, 5),
                seed: rng.next_u64(),
            },
            shrink_chain,
            |c| {
                let mut rng = Rng::new(c.seed);
                let d = c.b * c.r;
                let chain = GsChain::gs_kn(d, c.b, c.m, &mut rng, false);
                let x = Mat::randn(d, c.t, 1.0, &mut rng);
                let mut q = Mat::eye(d);
                for st in &chain.stages {
                    q = gemm_naive(&st.block.to_mat(), &gemm_naive(&st.perm.to_mat(), &q));
                }
                q = gemm_naive(&chain.p_out.to_mat(), &q);
                let want = gemm_naive(&q, &x);
                for ctx in [KernelCtx::default(), parallel_ctx()] {
                    assert!(chain_apply(&chain, &x, &ctx).fro_dist(&want) < 1e-9);
                }
            },
        );
    }

    #[test]
    fn gs_two_pass_apply_matches_dense() {
        prop::check("fused GsMatrix apply == dense", 1203, |rng| {
            let b = [2usize, 4][rng.below(2)];
            let r = prop::size_in(rng, 2, 4);
            let spec = GsSpec::gsoft(b * r, b);
            let a = spec.random_member(1.0, rng);
            let x = Mat::randn(spec.n(), prop::size_in(rng, 1, 4), 1.0, rng);
            let want = gemm_naive(&a.to_dense(), &x);
            for ctx in [KernelCtx::default(), parallel_ctx()] {
                assert!(gs_apply(&a, &x, &ctx).fro_dist(&want) < 1e-9);
            }
        });
    }

    #[test]
    fn batched_apply_matches_individual_applies() {
        let mut rng = Rng::new(77);
        let ctx = KernelCtx::default();
        let spec = GsSpec::gsoft(12, 3);
        let gs = spec.random_member(1.0, &mut rng);
        let xs: Vec<Mat> = (0..5).map(|_| Mat::randn(12, 4, 1.0, &mut rng)).collect();
        let batch = gs_apply_batch(&gs, &xs, &ctx);
        assert_eq!(batch.len(), xs.len());
        for (x, y) in xs.iter().zip(batch.iter()) {
            assert!(gs_apply(&gs, x, &ctx).fro_dist(y) < 1e-12);
        }
        let chain = GsChain::gs_kn(12, 3, 2, &mut rng, false);
        let ys = chain_apply_batch(&chain, &xs, &ctx);
        for (x, y) in xs.iter().zip(ys.iter()) {
            assert!(chain_apply(&chain, x, &ctx).fro_dist(y) < 1e-12);
        }
    }

    #[test]
    fn planned_operator_matches_one_shot_applies() {
        // FusedPlan/GsOp precompute gathers/scatters once; results must
        // be identical to the per-call fused_apply/gs_apply paths.
        prop::check("planned fused ops == one-shot fused ops", 1205, |rng| {
            let k = prop::size_in(rng, 1, 4);
            let br = prop::size_in(rng, 1, 4);
            let bc = prop::size_in(rng, 1, 4);
            let bd = BlockDiag::randn(k, br, bc, 1.0, rng);
            let p_in = Perm::random(bd.cols(), rng);
            let p_out = Perm::random(bd.rows(), rng);
            let x = Mat::randn(bd.cols(), prop::size_in(rng, 1, 4), 1.0, rng);
            let ctx = KernelCtx::default();
            let plan = FusedPlan::new(&bd, Some(&p_in), Some(&p_out));
            let want = fused_apply(&bd, Some(&p_in), Some(&p_out), &x, &ctx);
            assert!(plan.apply(&bd, &x, &ctx).fro_dist(&want) < 1e-15);

            let b = [2usize, 3][rng.below(2)];
            let r = prop::size_in(rng, 2, 4);
            let spec = GsSpec::gsoft(b * r, b);
            let gs = spec.random_member(1.0, rng);
            let xq = Mat::randn(spec.n(), 3, 1.0, rng);
            let want = gs_apply(&gs, &xq, &ctx);
            let op = GsOp::new(gs);
            assert!(op.apply(&xq, &ctx).fro_dist(&want) < 1e-15);
        });
    }

    #[test]
    fn relayouts_match_dense_permutation_products() {
        prop::check("kernel relayouts == dense P products", 1204, |rng| {
            let n = prop::size_in(rng, 1, 9);
            let p = Perm::random(n, rng);
            let a = Mat::randn(n, n, 1.0, rng);
            let pd = p.to_mat();
            assert!(permute_rows(&p, &a).fro_dist(&gemm_naive(&pd, &a)) < 1e-12);
            assert!(permute_cols(&p, &a).fro_dist(&gemm_naive(&a, &pd)) < 1e-12);
        });
    }

    #[test]
    #[should_panic(expected = "fused apply shape mismatch")]
    fn fused_shape_mismatch_is_a_hard_assert() {
        let bd = BlockDiag::zeros(2, 3, 3);
        fused_apply(&bd, None, None, &Mat::zeros(5, 2), &KernelCtx::default());
    }
}
