//! `gsoft conv-bench` — sweep the direct GS-SOC convolution runtime
//! across `(c, k, H·W, groups, batch)` configs and build the
//! machine-readable `BENCH_conv.json` record.
//!
//! The record builder lives in the library (not `main.rs`) so the
//! integration suite can assert the determinism contract: same seed ⇒
//! bit-identical records modulo the timing fields ([`strip_timing`]).
//! Everything except the `timings` sub-objects is a pure function of
//! `(opts, ctx)` — configs, dimensions, and the numeric `checksum`s of
//! the dispatched conv and GS-SOC outputs (the kernels are deterministic
//! even on the parallel row-panel paths, which split by rows without
//! reassociating any accumulation).

use std::time::Duration;

use crate::linalg::Mat;
use crate::report::{fmt, Table};
use crate::util::bench::{black_box, Bench};
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::conv::{conv_apply, conv_exp_apply, GsSocLayer};
use super::dispatch::KernelCtx;

/// Taylor terms used for the exponential timers (SOC uses ~6 in practice).
pub const BENCH_TERMS: usize = 6;

/// Dense materialized-operator baseline is only timed below this flat
/// dimension (the `(c·H·W)²` matrix is the thing the runtime exists to
/// avoid; at d=1024 it is already 8 MB).
pub const DENSE_BASELINE_MAX_D: usize = 1024;

#[derive(Clone, Copy, Debug)]
pub struct ConvBenchOpts {
    pub smoke: bool,
    pub seed: u64,
    /// Override the per-timer measurement window (tests use a few ms).
    pub measure: Option<Duration>,
}

/// One sweep point.
#[derive(Clone, Copy, Debug)]
pub struct ConvConfig {
    pub c: usize,
    pub k: usize,
    pub h: usize,
    pub w: usize,
    pub groups: usize,
    pub batch: usize,
}

/// The sweep grid. `--smoke` runs one small config (the CI gate); the
/// full grid covers small/large channel counts, both conv dispatch
/// paths, grouped and ungrouped kernels.
pub fn grid(smoke: bool) -> Vec<ConvConfig> {
    if smoke {
        return vec![ConvConfig {
            c: 8,
            k: 3,
            h: 8,
            w: 8,
            groups: 2,
            batch: 4,
        }];
    }
    let mut g = Vec::new();
    // One small config keeps the dense materialized-operator baseline
    // (d ≤ DENSE_BASELINE_MAX_D) in the full sweep, so the headline
    // direct-vs-dense speedup column is never empty outside --smoke.
    g.push(ConvConfig {
        c: 8,
        k: 3,
        h: 8,
        w: 8,
        groups: 2,
        batch: 8,
    });
    for c in [16usize, 32] {
        for hw in [16usize, 32] {
            for groups in [1usize, 4] {
                g.push(ConvConfig {
                    c,
                    k: 3,
                    h: hw,
                    w: hw,
                    groups,
                    batch: 8,
                });
            }
        }
    }
    g
}

/// Run the sweep: returns the human table and the `BENCH_conv.json`
/// record. Pure apart from timing — see the module docs.
pub fn record(opts: &ConvBenchOpts, ctx: &KernelCtx) -> (Table, Json) {
    let mut bench = Bench::new("conv_bench");
    if let Some(m) = opts.measure {
        // Tests shorten both windows this way instead of mutating the
        // process-global GSOFT_BENCH_QUICK (setenv is not thread-safe in
        // a threaded test binary).
        bench.measure_time(m);
        bench.warmup_time(m);
    }
    let mut rng = Rng::new(opts.seed);
    let mut table = Table::new(
        "conv-bench — direct GS-SOC convolution runtime vs materialized dense operator",
        &[
            "config",
            "direct p50 (µs)",
            "im2col p50 (µs)",
            "dispatch p50 (µs)",
            "conv_exp p50 (µs)",
            "gs-soc p50 (µs)",
            "dense p50 (µs)",
            "direct speedup vs dense",
        ],
    );
    let direct_ctx = KernelCtx {
        naive_below_flops: usize::MAX,
        ..*ctx
    };
    let im2col_ctx = KernelCtx {
        naive_below_flops: 0,
        ..*ctx
    };
    let mut configs = Vec::new();
    for cfg in grid(opts.smoke) {
        let d = cfg.c * cfg.h * cfg.w;
        let layer = GsSocLayer::random(
            cfg.c,
            cfg.k,
            cfg.groups,
            cfg.h,
            cfg.w,
            BENCH_TERMS,
            0.2 / (cfg.k * cfg.k) as f64,
            &mut rng,
        );
        let kern = layer.kern.clone();
        let x = Mat::randn(d, cfg.batch, 1.0, &mut rng);
        let tag = format!(
            "c{}_k{}_{}x{}_g{}_t{}",
            cfg.c, cfg.k, cfg.h, cfg.w, cfg.groups, cfg.batch
        );
        let direct = bench
            .bench(&format!("conv_direct/{tag}"), || {
                black_box(conv_apply(&kern, &x, cfg.h, cfg.w, &direct_ctx))
            })
            .clone();
        let im2col = bench
            .bench(&format!("conv_im2col/{tag}"), || {
                black_box(conv_apply(&kern, &x, cfg.h, cfg.w, &im2col_ctx))
            })
            .clone();
        let dispatch = bench
            .bench(&format!("conv_dispatch/{tag}"), || {
                black_box(conv_apply(&kern, &x, cfg.h, cfg.w, ctx))
            })
            .clone();
        let cexp = bench
            .bench(&format!("conv_exp/{tag}"), || {
                black_box(conv_exp_apply(&kern, &x, cfg.h, cfg.w, BENCH_TERMS, ctx))
            })
            .clone();
        let soc = bench
            .bench(&format!("gs_soc_layer/{tag}"), || {
                black_box(layer.apply(&x, ctx))
            })
            .clone();
        // Materialized-operator baseline: the dense (c·h·w)² matrix the
        // old gs/conv.rs path would build, applied with the dispatched
        // GEMM (materialization cost excluded — apply cost only).
        let dense = (d <= DENSE_BASELINE_MAX_D).then(|| {
            let q = kern.to_dense().to_matrix(cfg.h, cfg.w);
            bench
                .bench(&format!("dense_apply/{tag}"), || black_box(ctx.gemm(&q, &x)))
                .clone()
        });
        let speedup = dense
            .as_ref()
            .map(|s| s.p50_ns / direct.p50_ns.max(1.0));

        // Deterministic output checksums (timing-independent).
        let checksum: f64 = conv_apply(&kern, &x, cfg.h, cfg.w, ctx).data.iter().sum();
        let soc_checksum: f64 = layer.apply(&x, ctx).data.iter().sum();

        table.row(vec![
            tag,
            fmt(direct.p50_ns / 1e3, 1),
            fmt(im2col.p50_ns / 1e3, 1),
            fmt(dispatch.p50_ns / 1e3, 1),
            fmt(cexp.p50_ns / 1e3, 1),
            fmt(soc.p50_ns / 1e3, 1),
            dense
                .as_ref()
                .map(|s| fmt(s.p50_ns / 1e3, 1))
                .unwrap_or_else(|| "-".into()),
            speedup
                .map(|s| format!("{}x", fmt(s, 2)))
                .unwrap_or_else(|| "-".into()),
        ]);
        configs.push(Json::obj(vec![
            ("c", Json::Num(cfg.c as f64)),
            ("k", Json::Num(cfg.k as f64)),
            ("h", Json::Num(cfg.h as f64)),
            ("w", Json::Num(cfg.w as f64)),
            ("groups", Json::Num(cfg.groups as f64)),
            ("batch", Json::Num(cfg.batch as f64)),
            ("d", Json::Num(d as f64)),
            ("checksum", Json::Num(checksum)),
            ("gs_soc_checksum", Json::Num(soc_checksum)),
            (
                "timings",
                Json::obj(vec![
                    ("direct", direct.to_json()),
                    ("im2col", im2col.to_json()),
                    ("dispatch", dispatch.to_json()),
                    ("conv_exp", cexp.to_json()),
                    ("gs_soc", soc.to_json()),
                    (
                        "dense",
                        dense.as_ref().map(|s| s.to_json()).unwrap_or(Json::Null),
                    ),
                    (
                        "direct_speedup_vs_dense",
                        speedup.map(Json::Num).unwrap_or(Json::Null),
                    ),
                ]),
            ),
        ]));
    }
    bench.finish();
    let record = Json::obj(vec![
        ("smoke", Json::Bool(opts.smoke)),
        ("seed", Json::Num(opts.seed as f64)),
        ("terms", Json::Num(BENCH_TERMS as f64)),
        ("workers", Json::Num(ctx.workers as f64)),
        ("configs", Json::Arr(configs)),
    ]);
    (table, record)
}

/// Drop the timing fields from a bench record: every `timings` sub-object
/// (and any `wall_s`), recursively. What remains must be bit-identical
/// across runs with the same seed — the determinism contract the
/// integration suite enforces on `BENCH_*.json` records.
pub fn strip_timing(j: &Json) -> Json {
    match j {
        Json::Obj(m) => Json::Obj(
            m.iter()
                .filter(|(k, _)| k.as_str() != "timings" && k.as_str() != "wall_s")
                .map(|(k, v)| (k.clone(), strip_timing(v)))
                .collect(),
        ),
        Json::Arr(v) => Json::Arr(v.iter().map(strip_timing).collect()),
        other => other.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_shapes_are_valid() {
        for smoke in [true, false] {
            for cfg in grid(smoke) {
                assert!(cfg.k % 2 == 1);
                assert_eq!(cfg.c % cfg.groups, 0);
                assert!(cfg.batch >= 1);
            }
        }
        assert_eq!(grid(true).len(), 1, "smoke runs exactly one config");
    }

    #[test]
    fn strip_timing_removes_only_timing_fields() {
        let j = Json::obj(vec![
            ("keep", Json::Num(1.0)),
            ("wall_s", Json::Num(2.0)),
            (
                "configs",
                Json::Arr(vec![Json::obj(vec![
                    ("d", Json::Num(64.0)),
                    ("timings", Json::obj(vec![("p50", Json::Num(5.0))])),
                ])]),
            ),
        ]);
        let s = strip_timing(&j);
        assert!(s.get("keep").is_some());
        assert!(s.get("wall_s").is_none());
        let cfg = &s.get("configs").unwrap().as_arr().unwrap()[0];
        assert!(cfg.get("d").is_some());
        assert!(cfg.get("timings").is_none());
    }
}
