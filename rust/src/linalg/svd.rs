//! One-sided Jacobi SVD.
//!
//! Algorithm 1 of the paper (projection onto the GS class) requires SVD
//! truncations of every `(P_L^T A P_R^T)` block; with no LAPACK available
//! we implement the one-sided Jacobi method, which is simple, numerically
//! robust, and exactly adequate for the `b×b` block sizes the paper uses
//! (8–128).

use super::mat::Mat;


/// Full SVD `a = u diag(s) v^T`, with `u`: m×k, `s` descending, `v`: n×k,
/// where `k = min(m, n)`.
pub struct Svd {
    pub u: Mat,
    pub s: Vec<f64>,
    pub v: Mat,
}

/// Compute the SVD of `a` by one-sided Jacobi on the (possibly implicitly
/// transposed) matrix with rows ≥ cols.
pub fn svd(a: &Mat) -> Svd {
    if a.rows >= a.cols {
        svd_tall(a)
    } else {
        // SVD(A^T) = (V, S, U).
        let t = svd_tall(&a.t());
        Svd {
            u: t.v,
            s: t.s,
            v: t.u,
        }
    }
}

/// Singular values only (descending).
pub fn singular_values(a: &Mat) -> Vec<f64> {
    svd(a).s
}

fn svd_tall(a: &Mat) -> Svd {
    let m = a.rows;
    let n = a.cols;
    debug_assert!(m >= n);
    // Work on W = A; rotate columns until pairwise orthogonal.
    let mut w = a.clone();
    let mut v = Mat::eye(n);

    let eps = 1e-14;
    let max_sweeps = 60;
    for _ in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Compute the 2x2 Gram entries for columns p, q.
                let mut app = 0.0;
                let mut aqq = 0.0;
                let mut apq = 0.0;
                for i in 0..m {
                    let wp = w[(i, p)];
                    let wq = w[(i, q)];
                    app += wp * wp;
                    aqq += wq * wq;
                    apq += wp * wq;
                }
                if apq.abs() <= eps * (app * aqq).sqrt() || apq == 0.0 {
                    continue;
                }
                off = off.max(apq.abs() / (app * aqq).sqrt().max(1e-300));
                // Jacobi rotation zeroing the (p,q) Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let wp = w[(i, p)];
                    let wq = w[(i, q)];
                    w[(i, p)] = c * wp - s * wq;
                    w[(i, q)] = s * wp + c * wq;
                }
                for i in 0..n {
                    let vp = v[(i, p)];
                    let vq = v[(i, q)];
                    v[(i, p)] = c * vp - s * vq;
                    v[(i, q)] = s * vp + c * vq;
                }
            }
        }
        if off < 1e-13 {
            break;
        }
    }

    // Column norms of W are the singular values.
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> = (0..n)
        .map(|j| (0..m).map(|i| w[(i, j)] * w[(i, j)]).sum::<f64>().sqrt())
        .collect();
    order.sort_by(|&x, &y| norms[y].partial_cmp(&norms[x]).unwrap());

    let mut u = Mat::zeros(m, n);
    let mut vv = Mat::zeros(n, n);
    let mut s = vec![0.0; n];
    for (idx, &j) in order.iter().enumerate() {
        s[idx] = norms[j];
        if norms[j] > 1e-300 {
            for i in 0..m {
                u[(i, idx)] = w[(i, j)] / norms[j];
            }
        }
        for i in 0..n {
            vv[(i, idx)] = v[(i, j)];
        }
    }
    // Zero singular values leave zero columns in U; replace them with an
    // orthonormal completion so U always has orthonormal columns.
    let zero_cols: Vec<usize> = (0..n).filter(|&j| s[j] <= 1e-300).collect();
    if !zero_cols.is_empty() {
        u = complete_orthonormal(&u, &zero_cols);
    }
    Svd { u, s, v: vv }
}

/// Replace the listed (zero) columns of `u` with vectors orthonormal to the
/// rest, via QR of [U | I-slices].
fn complete_orthonormal(u: &Mat, zero_cols: &[usize]) -> Mat {
    let m = u.rows;
    let n = u.cols;
    let mut out = u.clone();
    // Gram-Schmidt candidate basis vectors against current columns.
    let mut next_e = 0usize;
    for &jz in zero_cols {
        'candidates: while next_e < m {
            let mut cand = vec![0.0; m];
            cand[next_e] = 1.0;
            next_e += 1;
            // Orthogonalize against all current non-zero columns.
            for j in 0..n {
                if j == jz {
                    continue;
                }
                let dot: f64 = (0..m).map(|i| out[(i, j)] * cand[i]).sum();
                for i in 0..m {
                    cand[i] -= dot * out[(i, j)];
                }
            }
            let norm: f64 = cand.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm > 1e-8 {
                for i in 0..m {
                    out[(i, jz)] = cand[i] / norm;
                }
                break 'candidates;
            }
        }
    }
    out
}

/// Best rank-`r` approximation `a ≈ u_r diag(s_r) v_r^T`, returned as the
/// pair `(u_r * sqrt(s_r), v_r * sqrt(s_r))` — exactly the "pack
/// `U_r Σ_r^{1/2}` into L and `Σ_r^{1/2} V_r` into R" step of Algorithm 1.
pub fn truncated_factors(a: &Mat, r: usize) -> (Mat, Mat) {
    let Svd { u, s, v } = svd(a);
    let r = r.min(s.len());
    let mut uf = Mat::zeros(a.rows, r);
    let mut vf = Mat::zeros(a.cols, r);
    for j in 0..r {
        let sq = s[j].max(0.0).sqrt();
        for i in 0..a.rows {
            uf[(i, j)] = u[(i, j)] * sq;
        }
        for i in 0..a.cols {
            vf[(i, j)] = v[(i, j)] * sq;
        }
    }
    (uf, vf)
}

/// Spectral norm (largest singular value).
pub fn spectral_norm(a: &Mat) -> f64 {
    singular_values(a).first().copied().unwrap_or(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    fn reconstruct(d: &Svd, m: usize, n: usize) -> Mat {
        let k = d.s.len();
        let mut us = Mat::zeros(m, k);
        for i in 0..m {
            for j in 0..k {
                us[(i, j)] = d.u[(i, j)] * d.s[j];
            }
        }
        us.matmul(&d.v.t())
    }

    #[test]
    fn svd_reconstructs_random_matrices() {
        prop::check("SVD: A = U S V^T, factors orthonormal", 21, |rng| {
            let m = prop::size_in(rng, 1, 10);
            let n = prop::size_in(rng, 1, 10);
            let a = Mat::randn(m, n, 1.0, rng);
            let d = svd(&a);
            assert!(reconstruct(&d, m, n).fro_dist(&a) < 1e-8, "reconstruction");
            assert!(d.u.is_orthogonal(1e-8), "U orthonormal");
            assert!(d.v.is_orthogonal(1e-8), "V orthonormal");
            for w in d.s.windows(2) {
                assert!(w[0] >= w[1] - 1e-12, "descending");
            }
            assert!(d.s.iter().all(|&x| x >= 0.0), "non-negative");
        });
    }

    #[test]
    fn singular_values_of_orthogonal_are_ones() {
        let mut rng = Rng::new(9);
        let q = Mat::rand_orthogonal(12, &mut rng);
        for s in singular_values(&q) {
            assert!((s - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn truncation_is_optimal_rank_r() {
        // Build a matrix with known singular values; check Eckart–Young.
        let mut rng = Rng::new(10);
        let u = Mat::rand_orthogonal(8, &mut rng);
        let v = Mat::rand_orthogonal(6, &mut rng);
        let svals = [5.0, 3.0, 1.0, 0.5, 0.1, 0.01];
        let mut s = Mat::zeros(8, 6);
        for (i, &x) in svals.iter().enumerate() {
            s[(i, i)] = x;
        }
        let a = u.matmul(&s).matmul(&v.t());
        let (lf, rf) = truncated_factors(&a, 2);
        let approx = lf.matmul(&rf.t());
        let err = approx.fro_dist(&a);
        let expected: f64 = svals[2..].iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!((err - expected).abs() < 1e-8, "err={err} expected={expected}");
    }

    #[test]
    fn zero_and_degenerate_matrices() {
        let z = Mat::zeros(4, 3);
        let d = svd(&z);
        assert!(d.s.iter().all(|&x| x == 0.0));
        assert!(d.u.is_orthogonal(1e-9), "U completed to orthonormal");

        let mut one = Mat::zeros(3, 3);
        one[(1, 1)] = 2.5;
        let d = svd(&one);
        assert!((d.s[0] - 2.5).abs() < 1e-12);
        assert!(d.s[1].abs() < 1e-12);
    }

    #[test]
    fn spectral_norm_submultiplicative() {
        prop::check("||AB|| <= ||A|| ||B||", 33, |rng| {
            let a = Mat::randn(5, 4, 1.0, rng);
            let b = Mat::randn(4, 6, 1.0, rng);
            let ab = spectral_norm(&a.matmul(&b));
            assert!(ab <= spectral_norm(&a) * spectral_norm(&b) + 1e-9);
        });
    }
}
