//! Dense linear-algebra substrate, built from scratch (no LAPACK/BLAS in
//! this environment): row-major [`mat::Mat`], Householder [`qr`], one-sided
//! Jacobi [`svd`] (needed by the paper's Algorithm 1 projection), [`lu`]
//! solves, and the [`cayley`] orthogonal parametrization.

pub mod cayley;
pub mod lu;
pub mod mat;
pub mod qr;
pub mod svd;

pub use cayley::{cayley, cayley_unconstrained, skew};
pub use lu::{inverse, solve};
pub use mat::Mat;
pub use qr::qr;
pub use svd::{singular_values, spectral_norm, svd, Svd};
