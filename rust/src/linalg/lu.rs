//! LU decomposition with partial pivoting; linear solves and inverses.
//!
//! The Cayley transform `Q = (I + K)(I - K)^{-1}` needs a small dense
//! solve; blocks in this codebase are at most a few hundred on a side, so
//! textbook LU with partial pivoting is the right tool.

use super::mat::Mat;

/// Solve `a x = b` for (possibly multiple right-hand sides) `b`.
/// Returns `None` if `a` is singular to working precision.
pub fn solve(a: &Mat, b: &Mat) -> Option<Mat> {
    assert_eq!(a.rows, a.cols, "solve requires a square matrix");
    assert_eq!(a.rows, b.rows, "rhs row mismatch");
    let n = a.rows;
    let mut lu = a.clone();
    let mut x = b.clone();
    let mut piv: Vec<usize> = (0..n).collect();

    for k in 0..n {
        // Partial pivot.
        let mut pmax = k;
        for i in k + 1..n {
            if lu[(i, k)].abs() > lu[(pmax, k)].abs() {
                pmax = i;
            }
        }
        if lu[(pmax, k)].abs() < 1e-300 {
            return None;
        }
        if pmax != k {
            for j in 0..n {
                let t = lu[(k, j)];
                lu[(k, j)] = lu[(pmax, j)];
                lu[(pmax, j)] = t;
            }
            piv.swap(k, pmax);
            for j in 0..x.cols {
                let t = x[(k, j)];
                x[(k, j)] = x[(pmax, j)];
                x[(pmax, j)] = t;
            }
        }
        // Eliminate below.
        let pivot = lu[(k, k)];
        for i in k + 1..n {
            let f = lu[(i, k)] / pivot;
            lu[(i, k)] = f;
            for j in k + 1..n {
                let v = lu[(k, j)];
                lu[(i, j)] -= f * v;
            }
            for j in 0..x.cols {
                let v = x[(k, j)];
                x[(i, j)] -= f * v;
            }
        }
    }

    // Back substitution.
    for j in 0..x.cols {
        for i in (0..n).rev() {
            let mut s = x[(i, j)];
            for k in i + 1..n {
                s -= lu[(i, k)] * x[(k, j)];
            }
            x[(i, j)] = s / lu[(i, i)];
        }
    }
    Some(x)
}

/// Matrix inverse via LU. `None` when singular.
pub fn inverse(a: &Mat) -> Option<Mat> {
    solve(a, &Mat::eye(a.rows))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn solve_recovers_solution() {
        prop::check("LU: A (A^{-1} b) = b", 17, |rng| {
            let n = prop::size_in(rng, 1, 10);
            // Diagonally dominant => comfortably nonsingular.
            let mut a = Mat::randn(n, n, 1.0, rng);
            for i in 0..n {
                a[(i, i)] += n as f64 + 1.0;
            }
            let b = Mat::randn(n, prop::size_in(rng, 1, 3), 1.0, rng);
            let x = solve(&a, &b).expect("nonsingular");
            assert!(a.matmul(&x).fro_dist(&b) < 1e-8);
        });
    }

    #[test]
    fn inverse_times_matrix_is_identity() {
        let mut rng = Rng::new(2);
        let q = Mat::rand_orthogonal(7, &mut rng);
        let qi = inverse(&q).unwrap();
        assert!(q.matmul(&qi).fro_dist(&Mat::eye(7)) < 1e-9);
        // For orthogonal matrices the inverse is the transpose.
        assert!(qi.fro_dist(&q.t()) < 1e-9);
    }

    #[test]
    fn singular_matrix_is_detected() {
        let a = Mat::from_rows(2, 2, &[1.0, 2.0, 2.0, 4.0]);
        assert!(solve(&a, &Mat::eye(2)).is_none());
        assert!(inverse(&Mat::zeros(3, 3)).is_none());
    }

    #[test]
    fn pivoting_handles_zero_leading_entry() {
        let a = Mat::from_rows(2, 2, &[0.0, 1.0, 1.0, 0.0]);
        let b = Mat::from_rows(2, 1, &[3.0, 5.0]);
        let x = solve(&a, &b).unwrap();
        assert!((x[(0, 0)] - 5.0).abs() < 1e-12);
        assert!((x[(1, 0)] - 3.0).abs() < 1e-12);
    }
}
