//! Cayley parametrization of orthogonal matrices (paper §2).
//!
//! `Q = (I + K)(I - K)^{-1}` with `K = -K^T` skew-symmetric maps any
//! skew-symmetric matrix to an orthogonal matrix with `det = +1` (no -1
//! eigenvalue). OFT/BOFT/GSOFT all enforce per-block orthogonality this
//! way; the paper (and our L2 graphs) parametrize `K = A - A^T` from an
//! unconstrained square `A` for implementation convenience.

use super::lu;
use super::mat::Mat;

/// Skew-symmetrize: `K = A - A^T` (exactly what the paper trains).
pub fn skew(a: &Mat) -> Mat {
    assert_eq!(a.rows, a.cols);
    &a.clone() - &a.t()
}

/// Cayley transform of a skew-symmetric `K`:
/// `Q = (I + K)(I - K)^{-1}`.
///
/// `I - K` is always nonsingular for skew-symmetric `K` (its eigenvalues
/// are `1 - iλ`), so the unwrap is mathematically safe; we still surface
/// failure for non-skew inputs.
pub fn cayley(k: &Mat) -> Option<Mat> {
    assert_eq!(k.rows, k.cols);
    let n = k.rows;
    let i = Mat::eye(n);
    let i_minus = &i - k;
    let i_plus = &i + k;
    // (I+K)(I-K)^{-1} = solve((I-K)^T, (I+K)^T)^T ; both orders commute
    // for Cayley, but we keep the literal form for clarity.
    let inv = lu::solve(&i_minus, &i)?;
    Some(i_plus.matmul(&inv))
}

/// Cayley transform from an unconstrained matrix: `cayley(A - A^T)`.
pub fn cayley_unconstrained(a: &Mat) -> Mat {
    cayley(&skew(a)).expect("I - K is nonsingular for skew K")
}

/// Inverse Cayley: recover `K` from an orthogonal `Q` with no -1
/// eigenvalue: `K = (Q - I)(Q + I)^{-1}`.
pub fn cayley_inverse(q: &Mat) -> Option<Mat> {
    assert_eq!(q.rows, q.cols);
    let n = q.rows;
    let i = Mat::eye(n);
    let inv = lu::inverse(&(&q.clone() + &i))?;
    Some((&q.clone() - &i).matmul(&inv))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn cayley_is_orthogonal() {
        prop::check("cayley(A - A^T) is orthogonal", 29, |rng| {
            let n = prop::size_in(rng, 1, 12);
            let a = Mat::randn(n, n, 1.0, rng);
            let q = cayley_unconstrained(&a);
            assert!(q.is_orthogonal(1e-8), "err={}", q.orthogonality_error());
        });
    }

    #[test]
    fn zero_k_gives_identity() {
        // Identity initialization (paper §6.1: init Q = I by K = 0).
        let q = cayley(&Mat::zeros(5, 5)).unwrap();
        assert!(q.fro_dist(&Mat::eye(5)) < 1e-12);
    }

    #[test]
    fn skew_output_is_skew() {
        prop::check("K = A - A^T is skew", 31, |rng| {
            let n = prop::size_in(rng, 1, 8);
            let k = skew(&Mat::randn(n, n, 1.0, rng));
            assert!(k.fro_dist(&k.t().scale(-1.0)) < 1e-12);
            for i in 0..n {
                assert!(k[(i, i)].abs() < 1e-12);
            }
        });
    }

    #[test]
    fn cayley_round_trip() {
        prop::check("cayley_inverse(cayley(K)) = K", 37, |rng| {
            let n = prop::size_in(rng, 1, 8);
            let k = skew(&Mat::randn(n, n, 0.5, rng));
            let q = cayley(&k).unwrap();
            let k2 = cayley_inverse(&q).unwrap();
            assert!(k.fro_dist(&k2) < 1e-7, "dist={}", k.fro_dist(&k2));
        });
    }

    #[test]
    fn determinant_stays_on_rotation_component() {
        // Cayley images are rotations: Q has no -1 eigenvalue, so a path
        // t -> cayley(tK) connects Q to I without leaving O(n); check det
        // via products of singular-value-signed QR... simpler: check
        // Q + I is nonsingular.
        let mut rng = Rng::new(5);
        for _ in 0..10 {
            let k = skew(&Mat::randn(6, 6, 1.0, &mut rng));
            let q = cayley(&k).unwrap();
            assert!(lu::inverse(&(&q + &Mat::eye(6))).is_some());
        }
    }
}
