//! Dense row-major matrix type and basic operations.
//!
//! Everything downstream (GS algebra, projection, Cayley, adapter merging)
//! is built on this type. Values are `f64` — the paper's constructions
//! (Cayley solves, blockwise SVD in Algorithm 1) are small but numerically
//! delicate, and model weights are converted at the f32 boundary only when
//! talking to PJRT buffers.

use std::fmt;
use std::ops::{Add, Mul, Sub};

use crate::util::rng::Rng;

/// Dense row-major matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:9.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "..." } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl Mat {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// From a row-major slice.
    pub fn from_rows(rows: usize, cols: usize, data: &[f64]) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat {
            rows,
            cols,
            data: data.to_vec(),
        }
    }

    /// From f32 data (PJRT buffers are f32).
    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat {
            rows,
            cols,
            data: data.iter().map(|&x| x as f64).collect(),
        }
    }

    /// To f32 row-major data.
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    /// Gaussian random matrix.
    pub fn randn(rows: usize, cols: usize, std: f64, rng: &mut Rng) -> Mat {
        Mat {
            rows,
            cols,
            data: (0..rows * cols).map(|_| rng.normal() * std).collect(),
        }
    }

    /// Random orthogonal matrix via QR of a Gaussian (Haar-ish; enough for
    /// property tests).
    pub fn rand_orthogonal(n: usize, rng: &mut Rng) -> Mat {
        let g = Mat::randn(n, n, 1.0, rng);
        let (q, r) = super::qr::qr(&g);
        // Fix signs so the distribution doesn't collapse (standard trick).
        let mut q = q;
        for j in 0..n {
            if r[(j, j)] < 0.0 {
                for i in 0..n {
                    q[(i, j)] = -q[(i, j)];
                }
            }
        }
        q
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Matrix transpose.
    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Matrix product, routed through the CPU kernel subsystem
    /// ([`crate::kernel`]): the dispatcher keeps the naive ikj loop for
    /// small shapes and switches to the cache-blocked (optionally
    /// row-parallel) GEMM for large ones. The original loop survives as
    /// [`crate::kernel::gemm_naive`], the property-test oracle. Panics
    /// with the offending shapes on dimension mismatch (a hard `assert!`,
    /// release builds included).
    pub fn matmul(&self, other: &Mat) -> Mat {
        crate::kernel::ctx().gemm(self, other)
    }

    /// Matrix-vector product (kernel-dispatched; see [`crate::kernel::gemv`]).
    /// Panics with the offending shapes on dimension mismatch.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        crate::kernel::ctx().gemv(self, x)
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// `||self - other||_F`.
    pub fn fro_dist(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }

    /// Deviation from orthogonality: `||A^T A - I||_F`.
    pub fn orthogonality_error(&self) -> f64 {
        let gram = self.t().matmul(self);
        gram.fro_dist(&Mat::eye(self.cols))
    }

    /// True when `||A^T A - I||_F <= tol`.
    pub fn is_orthogonal(&self, tol: f64) -> bool {
        self.orthogonality_error() <= tol
    }

    /// Extract the sub-block with rows `r0..r0+nr` and cols `c0..c0+nc`.
    pub fn block(&self, r0: usize, c0: usize, nr: usize, nc: usize) -> Mat {
        assert!(r0 + nr <= self.rows && c0 + nc <= self.cols, "block out of range");
        let mut out = Mat::zeros(nr, nc);
        for i in 0..nr {
            for j in 0..nc {
                out[(i, j)] = self[(r0 + i, c0 + j)];
            }
        }
        out
    }

    /// Write `b` into the sub-block starting at `(r0, c0)`.
    pub fn set_block(&mut self, r0: usize, c0: usize, b: &Mat) {
        assert!(r0 + b.rows <= self.rows && c0 + b.cols <= self.cols);
        for i in 0..b.rows {
            for j in 0..b.cols {
                self[(r0 + i, c0 + j)] = b[(i, j)];
            }
        }
    }

    /// Scale every entry.
    pub fn scale(&self, s: f64) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| x * s).collect(),
        }
    }

    /// Count entries with |a_ij| > tol (used by the density experiments).
    pub fn nnz(&self, tol: f64) -> usize {
        self.data.iter().filter(|x| x.abs() > tol).count()
    }

    /// Maximum absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, x| m.max(x.abs()))
    }

    /// Numerical rank: number of singular values above `tol * s_max`.
    pub fn rank(&self, tol: f64) -> usize {
        let sv = super::svd::singular_values(self);
        let smax = sv.first().copied().unwrap_or(0.0);
        if smax == 0.0 {
            return 0;
        }
        sv.iter().filter(|&&s| s > tol * smax).count()
    }
}

impl Add for &Mat {
    type Output = Mat;
    fn add(self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(other.data.iter())
                .map(|(a, b)| a + b)
                .collect(),
        }
    }
}

impl Sub for &Mat {
    type Output = Mat;
    fn sub(self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(other.data.iter())
                .map(|(a, b)| a - b)
                .collect(),
        }
    }
}

impl Mul for &Mat {
    type Output = Mat;
    fn mul(self, other: &Mat) -> Mat {
        self.matmul(other)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(1);
        let a = Mat::randn(5, 7, 1.0, &mut rng);
        assert!(a.fro_dist(&Mat::eye(5).matmul(&a)) < 1e-12);
        assert!(a.fro_dist(&a.matmul(&Mat::eye(7))) < 1e-12);
    }

    #[test]
    fn matmul_known_values() {
        let a = Mat::from_rows(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_rows(2, 2, &[1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_involution_and_product_rule() {
        prop::check("(AB)^T = B^T A^T", 42, |rng| {
            let (m, k, n) = (
                prop::size_in(rng, 1, 6),
                prop::size_in(rng, 1, 6),
                prop::size_in(rng, 1, 6),
            );
            let a = Mat::randn(m, k, 1.0, rng);
            let b = Mat::randn(k, n, 1.0, rng);
            assert!(a.t().t().fro_dist(&a) < 1e-12);
            let lhs = a.matmul(&b).t();
            let rhs = b.t().matmul(&a.t());
            assert!(lhs.fro_dist(&rhs) < 1e-10);
        });
    }

    #[test]
    fn matvec_matches_matmul() {
        prop::check("matvec = matmul column", 7, |rng| {
            let (m, n) = (prop::size_in(rng, 1, 8), prop::size_in(rng, 1, 8));
            let a = Mat::randn(m, n, 1.0, rng);
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let xm = Mat::from_rows(n, 1, &x);
            let y1 = a.matvec(&x);
            let y2 = a.matmul(&xm);
            for i in 0..m {
                assert!((y1[i] - y2[(i, 0)]).abs() < 1e-10);
            }
        });
    }

    #[test]
    #[should_panic(expected = "matmul shape mismatch: 2x3 @ 4x2")]
    fn matmul_mismatch_reports_shapes_in_release() {
        // A hard assert!, not debug_assert!: the tier-1 gate builds
        // --release, where debug_assert! would vanish.
        let _ = Mat::zeros(2, 3).matmul(&Mat::zeros(4, 2));
    }

    #[test]
    #[should_panic(expected = "matvec shape mismatch")]
    fn matvec_mismatch_reports_shapes_in_release() {
        let _ = Mat::zeros(2, 3).matvec(&[0.0; 5]);
    }

    #[test]
    fn rand_orthogonal_is_orthogonal() {
        let mut rng = Rng::new(3);
        for n in [1, 2, 5, 16] {
            let q = Mat::rand_orthogonal(n, &mut rng);
            assert!(q.is_orthogonal(1e-8), "n={n} err={}", q.orthogonality_error());
        }
    }

    #[test]
    fn block_get_set_round_trip() {
        let mut rng = Rng::new(4);
        let a = Mat::randn(6, 8, 1.0, &mut rng);
        let b = a.block(2, 3, 3, 4);
        let mut c = Mat::zeros(6, 8);
        c.set_block(2, 3, &b);
        assert_eq!(c.block(2, 3, 3, 4).data, b.data);
        assert_eq!(c[(0, 0)], 0.0);
    }

    #[test]
    fn rank_of_outer_product_is_one() {
        let mut rng = Rng::new(5);
        let u = Mat::randn(6, 1, 1.0, &mut rng);
        let v = Mat::randn(1, 5, 1.0, &mut rng);
        let a = u.matmul(&v);
        assert_eq!(a.rank(1e-9), 1);
        assert_eq!(Mat::eye(4).rank(1e-9), 4);
        assert_eq!(Mat::zeros(3, 3).rank(1e-9), 0);
    }

    #[test]
    fn f32_round_trip() {
        let mut rng = Rng::new(6);
        let a = Mat::randn(3, 4, 1.0, &mut rng);
        let b = Mat::from_f32(3, 4, &a.to_f32());
        assert!(a.fro_dist(&b) < 1e-6);
    }
}
