//! Householder QR decomposition.
//!
//! Used by Theorem 1's constructive proof path (orthonormalizing skeleton
//! factors of GS blocks) and by [`crate::linalg::mat::Mat::rand_orthogonal`].

use super::mat::Mat;

/// Thin QR: `a = q r`, `q` is `m×n` with orthonormal columns (m ≥ n), `r`
/// upper triangular `n×n`. For m < n returns the full-width factorization
/// (`q` m×m, `r` m×n).
pub fn qr(a: &Mat) -> (Mat, Mat) {
    let m = a.rows;
    let n = a.cols;
    let k = m.min(n);
    let mut r = a.clone();
    // Accumulate Q by applying the Householder reflectors to the identity.
    let mut q = Mat::eye(m);

    for j in 0..k {
        // Build the Householder vector for column j below the diagonal.
        let mut norm = 0.0;
        for i in j..m {
            norm += r[(i, j)] * r[(i, j)];
        }
        let norm = norm.sqrt();
        if norm == 0.0 {
            continue;
        }
        let alpha = if r[(j, j)] > 0.0 { -norm } else { norm };
        let mut v = vec![0.0; m - j];
        v[0] = r[(j, j)] - alpha;
        for i in j + 1..m {
            v[i - j] = r[(i, j)];
        }
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 == 0.0 {
            continue;
        }
        // Apply H = I - 2 v v^T / (v^T v) to R (columns j..n).
        for c in j..n {
            let dot: f64 = (j..m).map(|i| v[i - j] * r[(i, c)]).sum();
            let f = 2.0 * dot / vnorm2;
            for i in j..m {
                r[(i, c)] -= f * v[i - j];
            }
        }
        // Apply H to Q from the right: Q <- Q H (accumulates Q = H1 H2 ...).
        for rr in 0..m {
            let dot: f64 = (j..m).map(|i| v[i - j] * q[(rr, i)]).sum();
            let f = 2.0 * dot / vnorm2;
            for i in j..m {
                q[(rr, i)] -= f * v[i - j];
            }
        }
    }

    // Trim to thin factors when m >= n.
    if m >= n {
        let q_thin = q.block(0, 0, m, n);
        let r_thin = r.block(0, 0, n, n);
        (q_thin, r_thin)
    } else {
        (q, r)
    }
}

/// Orthonormalize the columns of `a` (Q factor of thin QR).
pub fn orthonormal_columns(a: &Mat) -> Mat {
    qr(a).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn qr_reconstructs_and_q_orthonormal() {
        prop::check("QR: A = QR, Q^T Q = I, R upper-tri", 13, |rng| {
            let m = prop::size_in(rng, 1, 10);
            let n = prop::size_in(rng, 1, m);
            let a = Mat::randn(m, n, 1.0, rng);
            let (q, r) = qr(&a);
            assert_eq!((q.rows, q.cols), (m, n));
            assert_eq!((r.rows, r.cols), (n, n));
            assert!(q.matmul(&r).fro_dist(&a) < 1e-9, "reconstruction");
            assert!(q.is_orthogonal(1e-9), "orthonormal columns");
            for i in 0..n {
                for j in 0..i {
                    assert!(r[(i, j)].abs() < 1e-9, "R not upper triangular");
                }
            }
        });
    }

    #[test]
    fn qr_wide_matrix() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(3, 7, 1.0, &mut rng);
        let (q, r) = qr(&a);
        assert!(q.matmul(&r).fro_dist(&a) < 1e-9);
        assert!(q.is_orthogonal(1e-9));
    }

    #[test]
    fn qr_rank_deficient() {
        // A column of zeros must not produce NaNs.
        let mut a = Mat::zeros(4, 3);
        a[(0, 0)] = 1.0;
        a[(1, 2)] = 2.0;
        let (q, r) = qr(&a);
        assert!(q.matmul(&r).fro_dist(&a) < 1e-9);
        assert!(q.data.iter().all(|x| x.is_finite()));
    }
}
