//! # gsoft — Group-and-Shuffle structured orthogonal parametrization
//!
//! A production-shaped reproduction of *"Group and Shuffle: Efficient
//! Structured Orthogonal Parametrization"* (Gorbunov et al., NeurIPS 2024)
//! as a three-layer Rust + JAX + Pallas system:
//!
//! - **L1** (build-time Python): Pallas kernels for the block-diagonal /
//!   group-and-shuffle hot path, under `python/compile/kernels/`.
//! - **L2** (build-time Python): JAX models — GSOFT / Double GSOFT / OFT /
//!   BOFT / LoRA adapters on a transformer classifier, a diffusion-style
//!   denoiser, and 1-Lipschitz LipConvnets with GS orthogonal
//!   convolutions — AOT-lowered to HLO text in `artifacts/`.
//! - **L3** (this crate): the exact GS matrix algebra ([`gs`]), a dense
//!   linear-algebra substrate ([`linalg`]) whose hot paths run through the
//!   fused group-and-shuffle CPU kernel subsystem ([`kernel`] — the
//!   pure-Rust mirror of the L1 Pallas kernels), the PJRT runtime that executes
//!   the AOT artifacts ([`runtime`]), the fine-tuning coordinator
//!   ([`coordinator`]), synthetic workload generators ([`data`]), the
//!   experiment/reporting harness ([`report`]) that regenerates every
//!   table and figure of the paper, and the multi-tenant adapter serving
//!   engine ([`serve`]) backed by the persistent tiered adapter store
//!   ([`store`]), both dispatching through the open adapter-family API
//!   ([`adapter`]) and instrumented by the fleet telemetry subsystem
//!   ([`obs`]: metrics registry, latency histograms, request traces).
//!
//! See `DESIGN.md` for the systems inventory and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod adapter;
pub mod coordinator;
pub mod data;
pub mod gs;
pub mod kernel;
pub mod linalg;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod store;
pub mod util;
