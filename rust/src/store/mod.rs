//! Persistent tiered adapter store (DESIGN.md §7, §13).
//!
//! The paper's economics make a two-tier layout natural: GS-OFT adapter
//! *factors* are tiny (O(d·b) floats per layer) while *merged* dense
//! weights are O(d²) — so the store persists the cheap factors durably in
//! append-only segment logs and spills the expensive merged products to
//! a size-capped disk cache, hydrating either lazily:
//!
//! ```text
//!            RAM                          disk
//!   ┌─────────────────────┐   ┌─────────────────────────────┐
//!   │ Registry tenant map │◄──│ factor tier: shard{i}.log   │
//!   │ (hydrated entries)  │   │ GSAD records, tenant-hashed │  durable
//!   ├─────────────────────┤   ├─────────────────────────────┤
//!   │ MergedCache (LRU of │◄──│ spill tier: t{id}.gsad      │  cache
//!   │ merged weights)     │──►│ merged-weight files         │  (lossy)
//!   └─────────────────────┘   └─────────────────────────────┘
//! ```
//!
//! - [`gsad`] — the versioned `GSAD` record format (shared
//!   [`crate::util::container`] framing, per-section CRC32);
//! - [`log`] — one append-only segment log: synced appends, tombstone
//!   deletes, torn-tail recovery, compaction past a garbage ratio;
//! - [`shard`] — N independent segment logs partitioned by tenant hash:
//!   parallel appends, parallel boot replay, per-shard crash recovery;
//! - [`spill`] — the merged-weight disk tier, params-CRC-tagged so stale
//!   spills can never serve a re-registered tenant;
//! - [`maint`] — the background maintenance thread owning compaction and
//!   spill writes, so neither ever runs on a request;
//! - [`AdapterStore`] — the facade the serving registry mounts
//!   ([`crate::serve::Registry::with_store`]). All methods take `&self`:
//!   synchronization lives in the per-shard locks, so appends for
//!   different shards run in parallel.
//!
//! Durability invariants: an acknowledged `put` survives crash+reopen; a
//! torn tail loses only unacknowledged writes of its own shard; the
//! factor tier is the source of truth and the spill tier is a pure cache
//! (safe to `rm -rf`).

pub mod gsad;
pub mod log;
pub mod maint;
pub mod shard;
pub mod spill;

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::serve::registry::{AdapterEntry, TenantId};

pub use log::{LogOpts, LogStats, SegmentLog};
pub use maint::{MaintStats, Maintainer, DEFAULT_MAINT_INTERVAL_MS};
pub use shard::{shard_of, ShardedLog, DEFAULT_SHARDS};
pub use spill::{read_merged, PendingSpill, SpillStats, SpillTier};

/// File name of the pre-sharding single segment log. New stores never
/// create it; an existing one is migrated into the sharded layout on
/// open ([`ShardedLog::open`]).
pub const LOG_FILE: &str = "adapters.log";

/// The durable factor tier: tenant adapters in hash-sharded segment logs
/// under one directory. (The spill tier is owned by the serving engine,
/// which knows merged-model sizes and the load-vs-remerge break-even;
/// see [`crate::serve::EngineOpts::spill_dir`].)
pub struct AdapterStore {
    dir: PathBuf,
    log: Arc<ShardedLog>,
}

impl AdapterStore {
    /// Open (creating if needed) the store at `dir`, replaying its shards
    /// in parallel. Fresh directories get [`DEFAULT_SHARDS`] shards; an
    /// existing layout keeps its shard count.
    pub fn open(dir: impl AsRef<Path>) -> Result<AdapterStore> {
        AdapterStore::open_sharded_with(dir, DEFAULT_SHARDS, LogOpts::default())
    }

    pub fn open_with(dir: impl AsRef<Path>, opts: LogOpts) -> Result<AdapterStore> {
        AdapterStore::open_sharded_with(dir, DEFAULT_SHARDS, opts)
    }

    /// Open with an explicit shard count (`gsoft ... --shards N`). The
    /// count only applies to a fresh directory — reopening always honors
    /// the layout on disk.
    pub fn open_sharded(dir: impl AsRef<Path>, shards: usize) -> Result<AdapterStore> {
        AdapterStore::open_sharded_with(dir, shards, LogOpts::default())
    }

    pub fn open_sharded_with(
        dir: impl AsRef<Path>,
        shards: usize,
        opts: LogOpts,
    ) -> Result<AdapterStore> {
        let dir = dir.as_ref().to_path_buf();
        let log = Arc::new(ShardedLog::open(&dir, shards, opts)?);
        Ok(AdapterStore { dir, log })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn num_shards(&self) -> usize {
        self.log.num_shards()
    }

    /// The sharded log itself — shared with the background
    /// [`Maintainer`], which owns compaction while it runs.
    pub fn sharded_log(&self) -> Arc<ShardedLog> {
        Arc::clone(&self.log)
    }

    /// Durably persist (or overwrite) a tenant's adapter. On return the
    /// record is synced to disk and will survive crash + reopen. Holds
    /// only the tenant's shard lock — puts to other shards proceed in
    /// parallel.
    pub fn put(&self, tenant: TenantId, entry: &AdapterEntry) -> Result<()> {
        self.log.append(tenant, &gsad::encode_adapter(tenant, entry))
    }

    /// Load a tenant's adapter (CRC-verified), or `None` if absent.
    pub fn get(&self, tenant: TenantId) -> Result<Option<AdapterEntry>> {
        let Some(payload) = self.log.get(tenant)? else {
            return Ok(None);
        };
        match gsad::decode(&payload)? {
            gsad::Record::Adapter { tenant: t, entry } => {
                anyhow::ensure!(
                    t == tenant,
                    "store index points tenant {tenant} at a record for tenant {t}"
                );
                Ok(Some(entry))
            }
            _ => Err(anyhow!("store record for tenant {tenant} is not an adapter")),
        }
    }

    /// Tombstone a tenant. Returns `false` if it was not present.
    pub fn delete(&self, tenant: TenantId) -> Result<bool> {
        self.log.delete(tenant)
    }

    pub fn contains(&self, tenant: TenantId) -> bool {
        self.log.contains(tenant)
    }

    pub fn len(&self) -> usize {
        self.log.len()
    }

    pub fn is_empty(&self) -> bool {
        self.log.is_empty()
    }

    pub fn tenant_ids(&self) -> Vec<TenantId> {
        self.log.tenant_ids()
    }

    /// Force-compact every shard (normally the maintenance thread's job).
    pub fn compact(&self) -> Result<()> {
        self.log.compact_all()
    }

    pub fn garbage_ratio(&self) -> f64 {
        self.log.garbage_ratio()
    }

    pub fn log_stats(&self) -> LogStats {
        self.log.stats()
    }

    pub fn file_bytes(&self) -> u64 {
        self.log.file_bytes()
    }

    /// Point-in-time health probe for the `/healthz` endpoint.
    pub fn health(&self) -> StoreHealth {
        let probe = self.dir.join(".gsoft.healthz.probe");
        let dir_writable = match std::fs::write(&probe, b"ok") {
            Ok(()) => {
                let _ = std::fs::remove_file(&probe);
                true
            }
            Err(_) => false,
        };
        StoreHealth {
            tenants: self.len(),
            shards: self.num_shards(),
            file_bytes: self.file_bytes(),
            garbage_ratio: self.garbage_ratio(),
            truncated_tail_bytes: self.log_stats().truncated_tail_bytes,
            dir_writable,
        }
    }
}

/// Factor-tier health snapshot ([`AdapterStore::health`]).
#[derive(Clone, Copy, Debug)]
pub struct StoreHealth {
    pub tenants: usize,
    pub shards: usize,
    pub file_bytes: u64,
    pub garbage_ratio: f64,
    /// Bytes dropped at the last replay because a shard's tail record was
    /// torn. Non-zero means the *previous* process lost unacknowledged
    /// writes — surfaced so operators notice crashy restarts, and treated
    /// as unhealthy until a clean reopen clears it.
    pub truncated_tail_bytes: u64,
    /// Whether the store directory still accepts new files.
    pub dir_writable: bool,
}

impl StoreHealth {
    pub fn ok(&self) -> bool {
        self.dir_writable && self.truncated_tail_bytes == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::gsad::tests::{entries_equal, random_entry};
    use crate::util::rng::Rng;
    use crate::util::tmp::unique_temp_dir;

    #[test]
    fn put_get_delete_survive_reopen() {
        let dir = unique_temp_dir("store_basic");
        let mut rng = Rng::new(41);
        let entries: Vec<_> = (0..4).map(|i| random_entry(&mut rng, i)).collect();
        {
            let store = AdapterStore::open(&dir).unwrap();
            for (t, e) in entries.iter().enumerate() {
                store.put(t as TenantId, e).unwrap();
            }
            assert!(store.delete(2).unwrap());
            assert_eq!(store.len(), 3);
        }
        let store = AdapterStore::open(&dir).unwrap();
        assert_eq!(store.tenant_ids(), vec![0, 1, 3]);
        for t in [0usize, 1, 3] {
            let back = store.get(t as TenantId).unwrap().expect("live tenant");
            assert!(entries_equal(&back, &entries[t]), "tenant {t} drifted");
        }
        assert!(store.get(2).unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn overwrites_return_the_latest_version() {
        let dir = unique_temp_dir("store_update");
        let mut rng = Rng::new(42);
        let v1 = random_entry(&mut rng, 0);
        let v2 = random_entry(&mut rng, 0);
        let store = AdapterStore::open(&dir).unwrap();
        store.put(5, &v1).unwrap();
        store.put(5, &v2).unwrap();
        let back = store.get(5).unwrap().unwrap();
        assert!(entries_equal(&back, &v2));
        drop(store);
        let store = AdapterStore::open(&dir).unwrap();
        assert!(entries_equal(&store.get(5).unwrap().unwrap(), &v2));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn parallel_puts_to_many_shards_all_land() {
        // The narrowed locking contract: concurrent puts (different
        // tenants, hence mostly different shards) must all be durable and
        // readable — no lost updates, no torn index.
        let dir = unique_temp_dir("store_parallel");
        let store = AdapterStore::open_sharded(&dir, 8).unwrap();
        let entries: Vec<_> = {
            let mut rng = Rng::new(43);
            (0..32).map(|i| random_entry(&mut rng, i)).collect()
        };
        crate::util::pool::parallel_map(entries.len(), 8, |t| {
            store.put(t as TenantId, &entries[t]).unwrap();
        });
        assert_eq!(store.len(), 32);
        drop(store);
        let store = AdapterStore::open(&dir).unwrap();
        assert_eq!(store.num_shards(), 8, "reopen keeps the on-disk shard count");
        for (t, e) in entries.iter().enumerate() {
            let back = store.get(t as TenantId).unwrap().expect("live tenant");
            assert!(entries_equal(&back, e), "tenant {t} drifted");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
