//! Persistent tiered adapter store (DESIGN.md §7).
//!
//! The paper's economics make a two-tier layout natural: GS-OFT adapter
//! *factors* are tiny (O(d·b) floats per layer) while *merged* dense
//! weights are O(d²) — so the store persists the cheap factors durably in
//! an append-only segment log and spills the expensive merged products to
//! a size-capped disk cache, hydrating either lazily:
//!
//! ```text
//!            RAM                          disk
//!   ┌─────────────────────┐   ┌─────────────────────────────┐
//!   │ Registry tenant map │◄──│ factor tier: segment log of │
//!   │ (hydrated entries)  │   │ GSAD adapter records + index│  durable
//!   ├─────────────────────┤   ├─────────────────────────────┤
//!   │ MergedCache (LRU of │◄──│ spill tier: t{id}.gsad      │  cache
//!   │ merged weights)     │──►│ merged-weight files         │  (lossy)
//!   └─────────────────────┘   └─────────────────────────────┘
//! ```
//!
//! - [`gsad`] — the versioned `GSAD` record format (shared
//!   [`crate::util::container`] framing, per-section CRC32);
//! - [`log`] — the append-only segment log: synced appends, tombstone
//!   deletes, torn-tail recovery, synchronous compaction past a garbage
//!   ratio;
//! - [`spill`] — the merged-weight disk tier, params-CRC-tagged so stale
//!   spills can never serve a re-registered tenant;
//! - [`AdapterStore`] — the facade the serving registry mounts
//!   ([`crate::serve::Registry::with_store`]).
//!
//! Durability invariants: an acknowledged `put` survives crash+reopen; a
//! torn tail loses only unacknowledged writes; the factor tier is the
//! source of truth and the spill tier is a pure cache (safe to `rm -rf`).

pub mod gsad;
pub mod log;
pub mod spill;

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::serve::registry::{AdapterEntry, TenantId};

pub use log::{LogOpts, LogStats, SegmentLog};
pub use spill::{read_merged, PendingSpill, SpillStats, SpillTier};

/// File name of the factor-tier segment log inside a store directory.
pub const LOG_FILE: &str = "adapters.log";

/// The durable factor tier: tenant adapters in a segment log under one
/// directory. (The spill tier is owned by the serving engine, which knows
/// merged-model sizes and the load-vs-remerge break-even; see
/// [`crate::serve::EngineOpts::spill_dir`].)
pub struct AdapterStore {
    dir: PathBuf,
    log: SegmentLog,
}

impl AdapterStore {
    /// Open (creating if needed) the store at `dir`, replaying its log.
    pub fn open(dir: impl AsRef<Path>) -> Result<AdapterStore> {
        AdapterStore::open_with(dir, LogOpts::default())
    }

    pub fn open_with(dir: impl AsRef<Path>, opts: LogOpts) -> Result<AdapterStore> {
        let dir = dir.as_ref().to_path_buf();
        let log = SegmentLog::open(dir.join(LOG_FILE), opts)?;
        Ok(AdapterStore { dir, log })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Durably persist (or overwrite) a tenant's adapter. On return the
    /// record is synced to disk and will survive crash + reopen.
    pub fn put(&mut self, tenant: TenantId, entry: &AdapterEntry) -> Result<()> {
        self.log.append(tenant, &gsad::encode_adapter(tenant, entry))
    }

    /// Load a tenant's adapter (CRC-verified), or `None` if absent.
    pub fn get(&mut self, tenant: TenantId) -> Result<Option<AdapterEntry>> {
        let Some(payload) = self.log.get(tenant)? else {
            return Ok(None);
        };
        match gsad::decode(&payload)? {
            gsad::Record::Adapter { tenant: t, entry } => {
                anyhow::ensure!(
                    t == tenant,
                    "store index points tenant {tenant} at a record for tenant {t}"
                );
                Ok(Some(entry))
            }
            _ => Err(anyhow!("store record for tenant {tenant} is not an adapter")),
        }
    }

    /// Tombstone a tenant. Returns `false` if it was not present.
    pub fn delete(&mut self, tenant: TenantId) -> Result<bool> {
        self.log.delete(tenant)
    }

    pub fn contains(&self, tenant: TenantId) -> bool {
        self.log.contains(tenant)
    }

    pub fn len(&self) -> usize {
        self.log.len()
    }

    pub fn is_empty(&self) -> bool {
        self.log.is_empty()
    }

    pub fn tenant_ids(&self) -> Vec<TenantId> {
        self.log.tenant_ids()
    }

    /// Force a compaction (normally triggered automatically).
    pub fn compact(&mut self) -> Result<()> {
        self.log.compact()
    }

    pub fn garbage_ratio(&self) -> f64 {
        self.log.garbage_ratio()
    }

    pub fn log_stats(&self) -> LogStats {
        self.log.stats()
    }

    pub fn file_bytes(&self) -> u64 {
        self.log.file_bytes()
    }

    /// Point-in-time health probe for the `/healthz` endpoint.
    pub fn health(&self) -> StoreHealth {
        let probe = self.dir.join(".gsoft.healthz.probe");
        let dir_writable = match std::fs::write(&probe, b"ok") {
            Ok(()) => {
                let _ = std::fs::remove_file(&probe);
                true
            }
            Err(_) => false,
        };
        StoreHealth {
            tenants: self.len(),
            file_bytes: self.file_bytes(),
            garbage_ratio: self.garbage_ratio(),
            truncated_tail_bytes: self.log_stats().truncated_tail_bytes,
            dir_writable,
        }
    }
}

/// Factor-tier health snapshot ([`AdapterStore::health`]).
#[derive(Clone, Copy, Debug)]
pub struct StoreHealth {
    pub tenants: usize,
    pub file_bytes: u64,
    pub garbage_ratio: f64,
    /// Bytes dropped at the last replay because the tail record was torn.
    /// Non-zero means the *previous* process lost unacknowledged writes —
    /// surfaced so operators notice crashy restarts, and treated as
    /// unhealthy until a clean reopen clears it.
    pub truncated_tail_bytes: u64,
    /// Whether the store directory still accepts new files.
    pub dir_writable: bool,
}

impl StoreHealth {
    pub fn ok(&self) -> bool {
        self.dir_writable && self.truncated_tail_bytes == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::gsad::tests::{entries_equal, random_entry};
    use crate::util::rng::Rng;
    use crate::util::tmp::unique_temp_dir;

    #[test]
    fn put_get_delete_survive_reopen() {
        let dir = unique_temp_dir("store_basic");
        let mut rng = Rng::new(41);
        let entries: Vec<_> = (0..4).map(|i| random_entry(&mut rng, i)).collect();
        {
            let mut store = AdapterStore::open(&dir).unwrap();
            for (t, e) in entries.iter().enumerate() {
                store.put(t as TenantId, e).unwrap();
            }
            assert!(store.delete(2).unwrap());
            assert_eq!(store.len(), 3);
        }
        let mut store = AdapterStore::open(&dir).unwrap();
        assert_eq!(store.tenant_ids(), vec![0, 1, 3]);
        for t in [0usize, 1, 3] {
            let back = store.get(t as TenantId).unwrap().expect("live tenant");
            assert!(entries_equal(&back, &entries[t]), "tenant {t} drifted");
        }
        assert!(store.get(2).unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn overwrites_return_the_latest_version() {
        let dir = unique_temp_dir("store_update");
        let mut rng = Rng::new(42);
        let v1 = random_entry(&mut rng, 0);
        let v2 = random_entry(&mut rng, 0);
        let mut store = AdapterStore::open(&dir).unwrap();
        store.put(5, &v1).unwrap();
        store.put(5, &v2).unwrap();
        let back = store.get(5).unwrap().unwrap();
        assert!(entries_equal(&back, &v2));
        drop(store);
        let mut store = AdapterStore::open(&dir).unwrap();
        assert!(entries_equal(&store.get(5).unwrap().unwrap(), &v2));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
