//! Spill tier: a size-capped disk cache of *merged* dense weights.
//!
//! The RAM [`crate::serve::MergedCache`] holds the hot set; when it
//! evicts a tenant, the merged flat buffer can be spilled here instead of
//! discarded, so the next promotion pays a disk read (sequential, cheap)
//! instead of a full re-merge (Cayley solves + structured `Q·W`). The
//! engine consults the Theorem-2 load-vs-remerge break-even
//! ([`crate::serve::Policy::spill_pays_off`]) before enabling the tier.
//!
//! Each entry is one `GSAD` `merged` file (`t{id}.gsad`), CRC-checked and
//! tagged with a CRC of the adapter params it was merged from:
//! [`SpillTier::get`] takes the *expected* params CRC, so a spill
//! directory that outlives an adapter update (or is reused across
//! restarts) can never serve stale weights — the stale file is deleted
//! and the lookup is a miss. Eviction is oldest-first by insertion order
//! (rebuilt as ascending tenant id on reopen — deterministic, and good
//! enough for a cold tier whose hit pattern the RAM LRU already shapes).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::serve::registry::TenantId;

use super::gsad;

/// Monotonic counters (snapshot with [`SpillTier::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpillStats {
    pub hits: u64,
    pub misses: u64,
    pub puts: u64,
    pub evictions: u64,
    /// Files dropped because their CRC failed or their params tag was
    /// stale.
    pub invalidations: u64,
}

/// The size-capped disk tier.
pub struct SpillTier {
    dir: PathBuf,
    budget_bytes: u64,
    used_bytes: u64,
    /// Tenant → file size in bytes.
    index: HashMap<TenantId, u64>,
    /// Insertion order, oldest first (each tenant appears at most once).
    order: Vec<TenantId>,
    stats: SpillStats,
}

impl SpillTier {
    /// Open the tier at `dir` (created if absent), rebuilding the index
    /// from the `t{id}.gsad` files already present. Files over budget are
    /// evicted oldest-first immediately, so a shrunk budget takes effect
    /// on open.
    pub fn open(dir: impl AsRef<Path>, budget_bytes: u64) -> Result<SpillTier> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating spill dir {}", dir.display()))?;
        let mut entries: Vec<(TenantId, u64)> = Vec::new();
        for e in std::fs::read_dir(&dir)? {
            let e = e?;
            let name = e.file_name();
            let Some(name) = name.to_str() else { continue };
            // A crash between tmp-write and rename strands a `.gsad.tmp`
            // file the index would never see; reap it here so leaked
            // bytes cannot accumulate outside the budget accounting.
            if name.ends_with(".gsad.tmp") {
                let _ = std::fs::remove_file(e.path());
                continue;
            }
            let Some(id) = name
                .strip_prefix('t')
                .and_then(|s| s.strip_suffix(".gsad"))
                .and_then(|s| s.parse::<TenantId>().ok())
            else {
                continue;
            };
            entries.push((id, e.metadata()?.len()));
        }
        entries.sort_unstable_by_key(|&(id, _)| id);
        let mut tier = SpillTier {
            dir,
            budget_bytes,
            used_bytes: entries.iter().map(|&(_, b)| b).sum(),
            order: entries.iter().map(|&(id, _)| id).collect(),
            index: entries.into_iter().collect(),
            stats: SpillStats::default(),
        };
        while tier.used_bytes > tier.budget_bytes {
            if !tier.evict_oldest() {
                break;
            }
        }
        Ok(tier)
    }

    fn path_of(&self, tenant: TenantId) -> PathBuf {
        self.dir.join(format!("t{tenant}.gsad"))
    }

    fn remove_entry(&mut self, tenant: TenantId) {
        if let Some(bytes) = self.index.remove(&tenant) {
            self.used_bytes -= bytes;
            self.order.retain(|&t| t != tenant);
            let _ = std::fs::remove_file(self.path_of(tenant));
        }
    }

    fn evict_oldest(&mut self) -> bool {
        let Some(&oldest) = self.order.first() else {
            return false;
        };
        self.remove_entry(oldest);
        self.stats.evictions += 1;
        true
    }

    /// Write a tenant's merged weights, evicting oldest entries until the
    /// tier fits its budget. Returns `false` (storing nothing) when the
    /// single file would exceed the whole budget. The write is
    /// tmp-then-rename, so a crash mid-write leaves no torn entry.
    pub fn put(&mut self, tenant: TenantId, params_crc: u32, flat: &[f32]) -> Result<bool> {
        let bytes = gsad::encode_merged(tenant, params_crc, flat);
        let size = bytes.len() as u64;
        if size > self.budget_bytes {
            return Ok(false);
        }
        self.remove_entry(tenant);
        while self.used_bytes + size > self.budget_bytes {
            if !self.evict_oldest() {
                break;
            }
        }
        let path = self.path_of(tenant);
        let tmp = self.dir.join(format!("t{tenant}.gsad.tmp"));
        std::fs::write(&tmp, &bytes).with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("renaming spill file {}", path.display()))?;
        self.used_bytes += size;
        self.index.insert(tenant, size);
        self.order.push(tenant);
        self.stats.puts += 1;
        Ok(true)
    }

    /// Load a tenant's merged weights if present, fresh (the stored
    /// params CRC matches `expected_params_crc`), and intact (container
    /// CRC passes). Corrupt or stale entries are deleted and count as
    /// misses.
    pub fn get(&mut self, tenant: TenantId, expected_params_crc: u32) -> Option<Vec<f32>> {
        if !self.index.contains_key(&tenant) {
            self.stats.misses += 1;
            return None;
        }
        let loaded = std::fs::read(self.path_of(tenant))
            .ok()
            .and_then(|bytes| gsad::decode(&bytes).ok());
        match loaded {
            Some(gsad::Record::Merged {
                tenant: t,
                params_crc,
                flat,
            }) if t == tenant && params_crc == expected_params_crc => {
                self.stats.hits += 1;
                Some(flat)
            }
            _ => {
                // Corrupt, stale, or mislabeled: drop it.
                self.remove_entry(tenant);
                self.stats.invalidations += 1;
                self.stats.misses += 1;
                None
            }
        }
    }

    pub fn contains(&self, tenant: TenantId) -> bool {
        self.index.contains_key(&tenant)
    }

    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    pub fn used_bytes(&self) -> u64 {
        self.used_bytes
    }

    pub fn budget_bytes(&self) -> u64 {
        self.budget_bytes
    }

    pub fn stats(&self) -> SpillStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::unique_temp_dir;

    #[test]
    fn put_get_round_trip_and_stats() {
        let dir = unique_temp_dir("spill_basic");
        let mut tier = SpillTier::open(&dir, 1 << 20).unwrap();
        let flat = vec![0.25f32, -1.0, 3.5];
        assert!(tier.put(4, 0xAB, &flat).unwrap());
        assert_eq!(tier.get(4, 0xAB).as_deref(), Some(flat.as_slice()));
        assert!(tier.get(5, 0xAB).is_none(), "absent tenant");
        let s = tier.stats();
        assert_eq!((s.puts, s.hits, s.misses), (1, 1, 1));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_params_crc_invalidates_the_file() {
        // The adapter was updated after this merge was spilled: the tier
        // must refuse to serve the stale weights and delete the file.
        let dir = unique_temp_dir("spill_stale");
        let mut tier = SpillTier::open(&dir, 1 << 20).unwrap();
        tier.put(1, 0x11, &[1.0, 2.0]).unwrap();
        assert!(tier.get(1, 0x22).is_none(), "stale entry must miss");
        assert!(!tier.contains(1), "stale entry must be dropped");
        assert_eq!(tier.stats().invalidations, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_file_is_dropped_not_served() {
        let dir = unique_temp_dir("spill_corrupt");
        let mut tier = SpillTier::open(&dir, 1 << 20).unwrap();
        tier.put(2, 0x11, &[1.0; 16]).unwrap();
        // Flip a payload byte behind the tier's back.
        let path = dir.join("t2.gsad");
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 1] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        assert!(tier.get(2, 0x11).is_none());
        assert!(!tier.contains(2));
        assert_eq!(tier.stats().invalidations, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn budget_evicts_oldest_and_refuses_oversized() {
        let dir = unique_temp_dir("spill_budget");
        // Size one entry, then budget for about two.
        let mut probe = SpillTier::open(dir.join("probe"), u64::MAX).unwrap();
        probe.put(0, 0, &[0.0; 64]).unwrap();
        let one = probe.used_bytes();
        let mut tier = SpillTier::open(dir.join("tier"), 2 * one + one / 2).unwrap();
        assert!(tier.put(1, 0, &[1.0; 64]).unwrap());
        assert!(tier.put(2, 0, &[2.0; 64]).unwrap());
        assert!(tier.put(3, 0, &[3.0; 64]).unwrap());
        assert!(!tier.contains(1), "oldest evicted");
        assert!(tier.contains(2) && tier.contains(3));
        assert!(tier.used_bytes() <= tier.budget_bytes());
        assert_eq!(tier.stats().evictions, 1);
        // A single entry larger than the whole budget is refused.
        let mut tiny = SpillTier::open(dir.join("tiny"), 16).unwrap();
        assert!(!tiny.put(9, 0, &[0.0; 1024]).unwrap());
        assert!(tiny.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reopen_rebuilds_the_index_from_disk() {
        let dir = unique_temp_dir("spill_reopen");
        {
            let mut tier = SpillTier::open(&dir, 1 << 20).unwrap();
            tier.put(7, 0x77, &[7.0; 8]).unwrap();
            tier.put(8, 0x88, &[8.0; 8]).unwrap();
        }
        // An orphaned tmp file (crash between write and rename) must be
        // reaped by the scan, not leak outside the budget accounting.
        std::fs::write(dir.join("t9.gsad.tmp"), b"torn").unwrap();
        let mut tier = SpillTier::open(&dir, 1 << 20).unwrap();
        assert_eq!(tier.len(), 2);
        assert!(
            !dir.join("t9.gsad.tmp").exists(),
            "orphaned tmp files must be deleted on open"
        );
        assert_eq!(tier.get(7, 0x77).as_deref(), Some(&[7.0f32; 8][..]));
        assert_eq!(tier.get(8, 0x88).as_deref(), Some(&[8.0f32; 8][..]));
        // Reopen with a tiny budget drops entries to fit.
        drop(tier);
        let tier = SpillTier::open(&dir, 8).unwrap();
        assert!(tier.used_bytes() <= 8);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
