//! Spill tier: a size-capped disk cache of *merged* dense weights.
//!
//! The RAM [`crate::serve::MergedCache`] holds the hot set; when it
//! evicts a tenant, the merged flat buffer can be spilled here instead of
//! discarded, so the next promotion pays a disk read (sequential, cheap)
//! instead of a full re-merge (Cayley solves + structured `Q·W`). The
//! engine consults the Theorem-2 load-vs-remerge break-even
//! ([`crate::serve::Policy::spill_pays_off`]) before enabling the tier.
//!
//! Each entry is one `GSAD` `merged` file (`t{id}.gsad`), CRC-checked and
//! tagged with a CRC of the adapter params it was merged from:
//! [`SpillTier::get`] takes the *expected* params CRC, so a spill
//! directory that outlives an adapter update (or is reused across
//! restarts) can never serve stale weights — the stale file is deleted
//! and the lookup is a miss. Eviction is oldest-first by insertion order
//! (rebuilt as ascending tenant id on reopen — deterministic, and good
//! enough for a cold tier whose hit pattern the RAM LRU already shapes).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::serve::registry::TenantId;

use super::gsad;

/// Monotonic counters (snapshot with [`SpillTier::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpillStats {
    pub hits: u64,
    pub misses: u64,
    pub puts: u64,
    pub evictions: u64,
    /// Files dropped because their CRC failed or their params tag was
    /// stale.
    pub invalidations: u64,
}

/// The size-capped disk tier.
///
/// The API is split so callers behind a mutex can keep the *bulk* file
/// I/O — encoding, `fs::write`, `fs::read` — outside the lock:
/// [`SpillTier::reserve`] → [`PendingSpill::write`] →
/// [`SpillTier::commit`] / [`SpillTier::abort`] for puts, and
/// [`SpillTier::begin_get`] → [`read_merged`] →
/// [`SpillTier::record_hit`] / [`SpillTier::invalidate`] for gets.
/// File *unlinks* stay inside the lock-held phases (they are O(1)
/// metadata operations), which is what makes the concurrent interleaving
/// safe: a file is only ever deleted while the index provably still
/// points at that exact entry — a racing writer's freshly renamed file
/// can never be unlinked by a stale observer. Each committed entry
/// carries a generation tag; [`SpillTier::invalidate`] is a no-op when
/// the observed generation no longer matches (the entry was replaced
/// between the observation and the failed read, so the new entry must
/// survive). [`SpillTier::put`] / [`SpillTier::get`] remain as
/// single-threaded conveniences composed from the same phases.
pub struct SpillTier {
    dir: PathBuf,
    budget_bytes: u64,
    used_bytes: u64,
    /// Tenant → (file size in bytes, commit generation).
    index: HashMap<TenantId, (u64, u64)>,
    /// Insertion order, oldest first (each tenant appears at most once).
    order: Vec<TenantId>,
    /// Monotonic counter: unique tmp-file names for in-flight writes and
    /// generation tags for committed entries.
    seq: u64,
    stats: SpillStats,
}

/// A budget reservation handed out by [`SpillTier::reserve`]: the caller
/// performs the write (lock-free), then hands the ticket back to
/// [`SpillTier::commit`] or [`SpillTier::abort`].
pub struct PendingSpill {
    tenant: TenantId,
    size: u64,
    gen: u64,
    tmp: PathBuf,
    dst: PathBuf,
}

impl PendingSpill {
    /// The I/O half of a put: tmp-write then rename, so a crash mid-write
    /// leaves no torn entry. The rename atomically replaces any previous
    /// file for this tenant, so the reservation never needs to unlink it.
    pub fn write(&self, bytes: &[u8]) -> Result<()> {
        let t0 = crate::obs::enabled().then(std::time::Instant::now);
        std::fs::write(&self.tmp, bytes)
            .with_context(|| format!("writing {}", self.tmp.display()))?;
        std::fs::rename(&self.tmp, &self.dst)
            .with_context(|| format!("renaming spill file {}", self.dst.display()))?;
        if let Some(t0) = t0 {
            crate::obs::store().record_spill_write(t0.elapsed());
        }
        Ok(())
    }
}

impl SpillTier {
    /// Open the tier at `dir` (created if absent), rebuilding the index
    /// from the `t{id}.gsad` files already present. Files over budget are
    /// evicted oldest-first immediately, so a shrunk budget takes effect
    /// on open.
    pub fn open(dir: impl AsRef<Path>, budget_bytes: u64) -> Result<SpillTier> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating spill dir {}", dir.display()))?;
        let mut entries: Vec<(TenantId, u64)> = Vec::new();
        for e in std::fs::read_dir(&dir)? {
            let e = e?;
            let name = e.file_name();
            let Some(name) = name.to_str() else { continue };
            // A crash between tmp-write and rename strands a `.gsad.tmp`
            // file the index would never see; reap it here so leaked
            // bytes cannot accumulate outside the budget accounting.
            if name.ends_with(".gsad.tmp") {
                let _ = std::fs::remove_file(e.path());
                continue;
            }
            let Some(id) = name
                .strip_prefix('t')
                .and_then(|s| s.strip_suffix(".gsad"))
                .and_then(|s| s.parse::<TenantId>().ok())
            else {
                continue;
            };
            entries.push((id, e.metadata()?.len()));
        }
        entries.sort_unstable_by_key(|&(id, _)| id);
        let mut tier = SpillTier {
            dir,
            budget_bytes,
            used_bytes: entries.iter().map(|&(_, b)| b).sum(),
            order: entries.iter().map(|&(id, _)| id).collect(),
            index: entries
                .into_iter()
                .enumerate()
                .map(|(gen, (id, bytes))| (id, (bytes, gen as u64)))
                .collect(),
            seq: 0,
            stats: SpillStats::default(),
        };
        tier.seq = tier.index.len() as u64;
        while tier.used_bytes > tier.budget_bytes {
            if !tier.evict_oldest() {
                break;
            }
        }
        Ok(tier)
    }

    fn path_of(&self, tenant: TenantId) -> PathBuf {
        self.dir.join(format!("t{tenant}.gsad"))
    }

    /// Health probe for `/healthz`: can the tier still create files in
    /// its directory? Writes and removes a throwaway probe file (named so
    /// neither the index rebuild nor the tmp-reaper on reopen would ever
    /// pick it up); touches no index or budget state.
    pub fn probe_writable(&self) -> bool {
        let probe = self.dir.join(".gsoft.healthz.probe");
        match std::fs::write(&probe, b"ok") {
            Ok(()) => {
                let _ = std::fs::remove_file(&probe);
                true
            }
            Err(_) => false,
        }
    }

    /// Drop a tenant from the index and budget accounting. Does NOT
    /// unlink the file — callers decide (a same-tenant re-put leaves the
    /// old file in place for the rename to replace atomically).
    fn detach(&mut self, tenant: TenantId) -> bool {
        let Some((bytes, _)) = self.index.remove(&tenant) else {
            return false;
        };
        self.used_bytes -= bytes;
        self.order.retain(|&t| t != tenant);
        true
    }

    /// Detach + unlink, while the entry is provably still this tenant's
    /// live one (call only with the tier lock held).
    fn remove_entry(&mut self, tenant: TenantId) {
        if self.detach(tenant) {
            let _ = std::fs::remove_file(self.path_of(tenant));
        }
    }

    fn evict_oldest(&mut self) -> bool {
        let Some(&oldest) = self.order.first() else {
            return false;
        };
        self.remove_entry(oldest);
        self.stats.evictions += 1;
        true
    }

    /// Phase 1 of a put (lock-held, metadata-only): admit `size` bytes
    /// for `tenant`, detaching the tenant's old entry (its file stays on
    /// disk — the commit rename replaces it atomically) and evicting
    /// oldest entries until the tier fits its budget. Returns `None`
    /// (storing nothing) when the single file would exceed the whole
    /// budget. The budget is charged immediately so concurrent
    /// reservations cannot oversubscribe it.
    pub fn reserve(&mut self, tenant: TenantId, size: u64) -> Option<PendingSpill> {
        if size > self.budget_bytes {
            return None;
        }
        self.detach(tenant);
        while self.used_bytes + size > self.budget_bytes {
            if !self.evict_oldest() {
                break;
            }
        }
        self.used_bytes += size;
        self.seq += 1;
        Some(PendingSpill {
            tenant,
            size,
            gen: self.seq,
            // Unique per reservation (concurrent same-tenant writers must
            // not share a tmp path); the suffix stays `.gsad.tmp` so
            // crash-orphans are reaped by the `open` scan.
            tmp: self.dir.join(format!("t{tenant}.{}.gsad.tmp", self.seq)),
            dst: self.path_of(tenant),
        })
    }

    /// Phase 2 of a put after [`PendingSpill::write`] landed: index the
    /// entry under its generation tag. If a racing put for the same
    /// tenant committed in between, its accounting is released (both
    /// renamed onto the same final path, so exactly one file exists).
    pub fn commit(&mut self, p: PendingSpill) {
        if let Some((old, _)) = self.index.insert(p.tenant, (p.size, p.gen)) {
            self.used_bytes -= old;
            self.order.retain(|&t| t != p.tenant);
        }
        self.order.push(p.tenant);
        self.stats.puts += 1;
    }

    /// Phase 2 of a put whose write failed: release the reservation.
    /// Any pre-existing file was left on disk (detached); a later `open`
    /// rescan re-indexes it, and the params-CRC guard keeps it safe.
    pub fn abort(&mut self, p: PendingSpill) {
        self.used_bytes -= p.size;
    }

    /// Phase 1 of a get (lock-held, metadata-only): the tenant's file
    /// path and current generation if indexed (read it with
    /// [`read_merged`], then report back with [`SpillTier::record_hit`]
    /// or [`SpillTier::invalidate`]); a miss is counted here.
    pub fn begin_get(&mut self, tenant: TenantId) -> Option<(PathBuf, u64)> {
        match self.index.get(&tenant) {
            Some(&(_, gen)) => Some((self.path_of(tenant), gen)),
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Phase 2 of a get whose read verified fresh and intact.
    pub fn record_hit(&mut self) {
        self.stats.hits += 1;
    }

    /// Phase 2 of a get whose read came back corrupt, stale, or missing:
    /// drop the entry — but only if it is still the generation observed
    /// at [`SpillTier::begin_get`]. If a racing put replaced the entry in
    /// between, the failed read says nothing about the *new* file, which
    /// must survive; the lookup is just a miss then.
    pub fn invalidate(&mut self, tenant: TenantId, observed_gen: u64) {
        self.stats.misses += 1;
        if self.index.get(&tenant).is_some_and(|&(_, gen)| gen == observed_gen) {
            self.stats.invalidations += 1;
            self.remove_entry(tenant);
        }
    }

    /// Write a tenant's merged weights (single-threaded convenience:
    /// [`SpillTier::reserve`] → [`PendingSpill::write`] →
    /// [`SpillTier::commit`]). Returns `false` when the file exceeds the
    /// whole budget.
    pub fn put(&mut self, tenant: TenantId, params_crc: u32, flat: &[f32]) -> Result<bool> {
        let bytes = gsad::encode_merged(tenant, params_crc, flat);
        let Some(pending) = self.reserve(tenant, bytes.len() as u64) else {
            return Ok(false);
        };
        match pending.write(&bytes) {
            Ok(()) => {
                self.commit(pending);
                Ok(true)
            }
            Err(e) => {
                self.abort(pending);
                Err(e)
            }
        }
    }

    /// Load a tenant's merged weights if present, fresh (the stored
    /// params CRC matches `expected_params_crc`), and intact (container
    /// CRC passes). Corrupt or stale entries are deleted and count as
    /// misses. (Single-threaded convenience over the split-phase API.)
    pub fn get(&mut self, tenant: TenantId, expected_params_crc: u32) -> Option<Vec<f32>> {
        let (path, gen) = self.begin_get(tenant)?;
        match read_merged(&path, tenant, expected_params_crc) {
            Some(flat) => {
                self.record_hit();
                Some(flat)
            }
            _ => {
                self.invalidate(tenant, gen);
                None
            }
        }
    }

    pub fn contains(&self, tenant: TenantId) -> bool {
        self.index.contains_key(&tenant)
    }

    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    pub fn used_bytes(&self) -> u64 {
        self.used_bytes
    }

    pub fn budget_bytes(&self) -> u64 {
        self.budget_bytes
    }

    pub fn stats(&self) -> SpillStats {
        self.stats
    }
}

/// The I/O half of a spill lookup: read and decode one merged file,
/// verifying the container CRC, the tenant label, and the adapter-params
/// freshness tag. `None` for anything corrupt, stale, or mislabeled —
/// the caller decides whether to [`SpillTier::invalidate`]. Lock-free by
/// design (takes a path, not the tier).
pub fn read_merged(path: &Path, tenant: TenantId, expected_params_crc: u32) -> Option<Vec<f32>> {
    let t0 = crate::obs::enabled().then(std::time::Instant::now);
    let record = std::fs::read(path)
        .ok()
        .and_then(|bytes| gsad::decode(&bytes).ok())?;
    if let Some(t0) = t0 {
        crate::obs::store().record_spill_read(t0.elapsed());
    }
    match record {
        gsad::Record::Merged {
            tenant: t,
            params_crc,
            flat,
        } if t == tenant && params_crc == expected_params_crc => Some(flat),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::unique_temp_dir;

    #[test]
    fn put_get_round_trip_and_stats() {
        let dir = unique_temp_dir("spill_basic");
        let mut tier = SpillTier::open(&dir, 1 << 20).unwrap();
        let flat = vec![0.25f32, -1.0, 3.5];
        assert!(tier.put(4, 0xAB, &flat).unwrap());
        assert_eq!(tier.get(4, 0xAB).as_deref(), Some(flat.as_slice()));
        assert!(tier.get(5, 0xAB).is_none(), "absent tenant");
        let s = tier.stats();
        assert_eq!((s.puts, s.hits, s.misses), (1, 1, 1));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_params_crc_invalidates_the_file() {
        // The adapter was updated after this merge was spilled: the tier
        // must refuse to serve the stale weights and delete the file.
        let dir = unique_temp_dir("spill_stale");
        let mut tier = SpillTier::open(&dir, 1 << 20).unwrap();
        tier.put(1, 0x11, &[1.0, 2.0]).unwrap();
        assert!(tier.get(1, 0x22).is_none(), "stale entry must miss");
        assert!(!tier.contains(1), "stale entry must be dropped");
        assert_eq!(tier.stats().invalidations, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_file_is_dropped_not_served() {
        let dir = unique_temp_dir("spill_corrupt");
        let mut tier = SpillTier::open(&dir, 1 << 20).unwrap();
        tier.put(2, 0x11, &[1.0; 16]).unwrap();
        // Flip a payload byte behind the tier's back.
        let path = dir.join("t2.gsad");
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 1] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        assert!(tier.get(2, 0x11).is_none());
        assert!(!tier.contains(2));
        assert_eq!(tier.stats().invalidations, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn budget_evicts_oldest_and_refuses_oversized() {
        let dir = unique_temp_dir("spill_budget");
        // Size one entry, then budget for about two.
        let mut probe = SpillTier::open(dir.join("probe"), u64::MAX).unwrap();
        probe.put(0, 0, &[0.0; 64]).unwrap();
        let one = probe.used_bytes();
        let mut tier = SpillTier::open(dir.join("tier"), 2 * one + one / 2).unwrap();
        assert!(tier.put(1, 0, &[1.0; 64]).unwrap());
        assert!(tier.put(2, 0, &[2.0; 64]).unwrap());
        assert!(tier.put(3, 0, &[3.0; 64]).unwrap());
        assert!(!tier.contains(1), "oldest evicted");
        assert!(tier.contains(2) && tier.contains(3));
        assert!(tier.used_bytes() <= tier.budget_bytes());
        assert_eq!(tier.stats().evictions, 1);
        // A single entry larger than the whole budget is refused.
        let mut tiny = SpillTier::open(dir.join("tiny"), 16).unwrap();
        assert!(!tiny.put(9, 0, &[0.0; 1024]).unwrap());
        assert!(tiny.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn split_phase_put_matches_the_convenience_path() {
        // The engine runs reserve → write → commit with the bulk I/O
        // outside the tier lock; the composed phases must be
        // observationally identical to `put`, and an abort must release
        // the reservation.
        use crate::store::gsad::encode_merged;
        let dir = unique_temp_dir("spill_phases");
        let mut tier = SpillTier::open(&dir, 1 << 20).unwrap();
        let flat = vec![1.0f32; 32];
        let bytes = encode_merged(3, 0x33, &flat);
        let pending = tier.reserve(3, bytes.len() as u64).unwrap();
        assert_eq!(tier.used_bytes(), bytes.len() as u64, "budget charged up front");
        assert!(!tier.contains(3), "not indexed until commit");
        pending.write(&bytes).unwrap();
        tier.commit(pending);
        assert!(tier.contains(3));
        assert_eq!(tier.get(3, 0x33).as_deref(), Some(flat.as_slice()));
        assert_eq!(tier.stats().puts, 1);

        // Overwrite: the reservation detaches the old entry; the rename
        // replaces its file atomically, with no double accounting.
        let flat2 = vec![2.0f32; 32];
        let bytes2 = encode_merged(3, 0x44, &flat2);
        let pending = tier.reserve(3, bytes2.len() as u64).unwrap();
        pending.write(&bytes2).unwrap();
        tier.commit(pending);
        assert_eq!(tier.used_bytes(), bytes2.len() as u64, "no double accounting");
        assert_eq!(tier.get(3, 0x44).as_deref(), Some(flat2.as_slice()));

        // Abort releases the reserved bytes.
        let before = tier.used_bytes();
        let pending = tier.reserve(4, 64).unwrap();
        assert_eq!(tier.used_bytes(), before + 64);
        tier.abort(pending);
        assert_eq!(tier.used_bytes(), before);
        assert!(!tier.contains(4));

        // A failed read of a *replaced* generation must not drop the
        // replacement: observe gen, replace the entry, then invalidate
        // with the stale generation — the fresh entry survives.
        let (path, stale_gen) = tier.begin_get(3).unwrap();
        assert!(read_merged(&path, 3, 0x44).is_some());
        tier.put(3, 0x55, &flat).unwrap(); // replaces, new generation
        tier.invalidate(3, stale_gen);
        assert!(tier.contains(3), "stale-gen invalidation must not drop the fresh entry");
        assert_eq!(tier.get(3, 0x55).as_deref(), Some(flat.as_slice()));
        // With the live generation it does drop.
        let (_, live_gen) = tier.begin_get(3).unwrap();
        tier.invalidate(3, live_gen);
        assert!(!tier.contains(3));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reopen_rebuilds_the_index_from_disk() {
        let dir = unique_temp_dir("spill_reopen");
        {
            let mut tier = SpillTier::open(&dir, 1 << 20).unwrap();
            tier.put(7, 0x77, &[7.0; 8]).unwrap();
            tier.put(8, 0x88, &[8.0; 8]).unwrap();
        }
        // An orphaned tmp file (crash between write and rename) must be
        // reaped by the scan, not leak outside the budget accounting.
        std::fs::write(dir.join("t9.gsad.tmp"), b"torn").unwrap();
        let mut tier = SpillTier::open(&dir, 1 << 20).unwrap();
        assert_eq!(tier.len(), 2);
        assert!(
            !dir.join("t9.gsad.tmp").exists(),
            "orphaned tmp files must be deleted on open"
        );
        assert_eq!(tier.get(7, 0x77).as_deref(), Some(&[7.0f32; 8][..]));
        assert_eq!(tier.get(8, 0x88).as_deref(), Some(&[8.0f32; 8][..]));
        // Reopen with a tiny budget drops entries to fit.
        drop(tier);
        let tier = SpillTier::open(&dir, 8).unwrap();
        assert!(tier.used_bytes() <= 8);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
