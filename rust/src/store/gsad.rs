//! `GSAD` — the versioned on-disk container for adapter-store records.
//!
//! Every record is one [`crate::util::container`] frame (the same
//! magic + JSON header + raw little-endian f32 payload framing as
//! `GSCK` checkpoints) with per-section CRC32, under the `GSAD` magic.
//! Four record schemas share the format, discriminated by the header's
//! `"record"` field:
//!
//! - `adapter`   — one tenant's adapter: kind + flat spec + params slab;
//! - `merged`    — one tenant's merged dense weights (the spill tier's
//!   unit), tagged with a CRC of the adapter params it was merged from so
//!   a stale spill file can never serve a re-registered tenant;
//! - `tombstone` — a deletion marker in the segment log;
//! - `fleet`     — a whole-registry snapshot: base spec + weights plus
//!   every tenant's adapter in one file.
//!
//! Unknown versions and unknown record types are rejected up front, so a
//! future `v2` can change any schema without old readers misparsing it.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::adapter::{desc_from_json_versioned, desc_to_json, AdapterDesc};
use crate::coordinator::FlatSpec;
use crate::serve::registry::{AdapterEntry, BaseModel, TenantId};
use crate::util::container::{crc32_f32, Container};
use crate::util::json::Json;

/// Container magic for every adapter-store record.
pub const MAGIC: &[u8; 4] = b"GSAD";

/// Current format version; bump on any schema change.
pub const VERSION: usize = 1;

/// One decoded `GSAD` record (fleet snapshots decode via
/// [`decode_fleet`] instead — they are files, never log records).
pub enum Record {
    Adapter {
        tenant: TenantId,
        entry: AdapterEntry,
    },
    Merged {
        tenant: TenantId,
        /// CRC32 of the adapter params this merge was computed from.
        params_crc: u32,
        flat: Vec<f32>,
    },
    Tombstone {
        tenant: TenantId,
    },
}

/// CRC32 of an adapter's flat parameter slab — the tag that ties a
/// spilled merged model to the exact adapter version it came from.
pub fn params_crc(entry: &AdapterEntry) -> u32 {
    crc32_f32(&entry.params)
}

// ---- record encode/decode --------------------------------------------------
//
// The `"kind"` header object is the family's wire form
// ([`crate::adapter::desc_to_json`] / [`crate::adapter::desc_from_json`]):
// `{"kind": <tag>, <hp…>}`, byte-identical to the pre-trait enum encoding
// for the v1 families. There is no per-family code in this module — an
// unknown tag decodes to a clean "unknown adapter family" error, and new
// families persist here with zero edits.

fn base_meta(record: &str, tenant: TenantId) -> Vec<(&'static str, Json)> {
    vec![
        ("v", Json::Num(VERSION as f64)),
        ("record", Json::Str(record.to_string())),
        ("tenant", Json::Num(tenant as f64)),
    ]
}

/// Encode one tenant's adapter. Params round-trip bit-exactly (f32 LE
/// bytes), which is what makes store-backed serving bit-identical to
/// in-memory serving.
pub fn encode_adapter(tenant: TenantId, entry: &AdapterEntry) -> Vec<u8> {
    let mut meta = base_meta("adapter", tenant);
    meta.push(("kind", desc_to_json(&entry.desc)));
    meta.push(("spec", entry.spec.to_json()));
    let mut c = Container::new(meta);
    c.push("params", entry.params.as_ref().clone());
    c.encode(MAGIC, true)
}

/// Encode one tenant's merged dense weights for the spill tier.
pub fn encode_merged(tenant: TenantId, params_crc: u32, flat: &[f32]) -> Vec<u8> {
    let mut meta = base_meta("merged", tenant);
    meta.push(("params_crc", Json::Num(params_crc as f64)));
    let mut c = Container::new(meta);
    c.push("flat", flat.to_vec());
    c.encode(MAGIC, true)
}

/// Encode a deletion marker for the segment log.
pub fn encode_tombstone(tenant: TenantId) -> Vec<u8> {
    Container::new(base_meta("tombstone", tenant)).encode(MAGIC, true)
}

/// Decode a `"kind"` header object and, when the record predates the
/// family's current wire version, rewrite the slab through the family's
/// [`crate::adapter::AdapterFamily::migrate`] hook — so a v2 build keeps
/// reading the v1 records it persisted. Future versions were already
/// rejected by [`desc_from_json_versioned`].
fn decode_kind_migrated(
    kind: &Json,
    tenant: TenantId,
    params: &mut Vec<f32>,
    spec: &mut FlatSpec,
) -> Result<AdapterDesc> {
    let (desc, fv) = desc_from_json_versioned(kind)?;
    let current = desc.family().wire_version();
    if fv < current {
        desc.family()
            .migrate(desc.cfg(), fv, params, spec)
            .map_err(|e| {
                anyhow!("migrating tenant {tenant} ('{}' v{fv} -> v{current}): {e:#}", desc.tag())
            })?;
    }
    Ok(desc)
}

fn decode_common(c: &Container) -> Result<(String, TenantId)> {
    let v = c.meta_usize("v")?;
    anyhow::ensure!(v == VERSION, "unsupported GSAD version {v} (this reader is v{VERSION})");
    let record = c.meta_str("record")?.to_string();
    let tenant = c
        .meta_req("tenant")?
        .as_f64()
        .filter(|x| *x >= 0.0 && x.fract() == 0.0)
        .ok_or_else(|| anyhow!("GSAD 'tenant' is not a non-negative integer"))?
        as TenantId;
    Ok((record, tenant))
}

/// Decode any single-tenant record (adapter / merged / tombstone).
pub fn decode(bytes: &[u8]) -> Result<Record> {
    let c = Container::decode(bytes, MAGIC)?;
    let (record, tenant) = decode_common(&c)?;
    match record.as_str() {
        "adapter" => {
            let mut spec = FlatSpec::from_json(c.meta_req("spec")?)?;
            let mut params = c.get("params")?.to_vec();
            let desc = decode_kind_migrated(c.meta_req("kind")?, tenant, &mut params, &mut spec)?;
            anyhow::ensure!(
                params.len() == spec.size(),
                "GSAD adapter for tenant {tenant}: {} params but spec expects {}",
                params.len(),
                spec.size()
            );
            Ok(Record::Adapter {
                tenant,
                entry: AdapterEntry {
                    desc,
                    params: Arc::new(params),
                    spec: Arc::new(spec),
                },
            })
        }
        "merged" => Ok(Record::Merged {
            tenant,
            params_crc: c.meta_usize("params_crc")? as u32,
            flat: c.get("flat")?.to_vec(),
        }),
        "tombstone" => Ok(Record::Tombstone { tenant }),
        other => Err(anyhow!("unknown GSAD record type '{other}'")),
    }
}

// ---- fleet snapshot --------------------------------------------------------

/// Encode a whole-registry snapshot: the base model plus every tenant's
/// adapter, one self-contained file.
pub fn encode_fleet(base: &BaseModel, tenants: &[(TenantId, AdapterEntry)]) -> Vec<u8> {
    let adapters = Json::Arr(
        tenants
            .iter()
            .map(|(t, e)| {
                Json::obj(vec![
                    ("tenant", Json::Num(*t as f64)),
                    ("kind", desc_to_json(&e.desc)),
                    ("spec", e.spec.to_json()),
                ])
            })
            .collect(),
    );
    let mut c = Container::new(vec![
        ("v", Json::Num(VERSION as f64)),
        ("record", Json::Str("fleet".into())),
        ("base_spec", base.spec.to_json()),
        ("adapters", adapters),
    ]);
    c.push("base", base.weights.as_ref().clone());
    for (t, e) in tenants {
        c.push(&format!("t{t}"), e.params.as_ref().clone());
    }
    c.encode(MAGIC, true)
}

/// Decode a fleet snapshot into (base weights, base spec, adapters).
#[allow(clippy::type_complexity)]
pub fn decode_fleet(bytes: &[u8]) -> Result<(Vec<f32>, FlatSpec, Vec<(TenantId, AdapterEntry)>)> {
    let c = Container::decode(bytes, MAGIC)?;
    let v = c.meta_usize("v")?;
    anyhow::ensure!(v == VERSION, "unsupported GSAD version {v} (this reader is v{VERSION})");
    anyhow::ensure!(
        c.meta_str("record")? == "fleet",
        "not a fleet snapshot (record = '{}')",
        c.meta_str("record")?
    );
    let base_spec = FlatSpec::from_json(c.meta_req("base_spec")?)?;
    let base = c.get("base")?.to_vec();
    let mut tenants = Vec::new();
    for a in c
        .meta_req("adapters")?
        .as_arr()
        .ok_or_else(|| anyhow!("fleet 'adapters' is not an array"))?
    {
        let tenant = a
            .req("tenant")
            .map_err(|e| anyhow!("{e}"))?
            .as_f64()
            .filter(|x| *x >= 0.0 && x.fract() == 0.0)
            .ok_or_else(|| anyhow!("fleet tenant id is not a non-negative integer"))?
            as TenantId;
        let mut spec = FlatSpec::from_json(a.req("spec").map_err(|e| anyhow!("{e}"))?)?;
        let mut params = c.get(&format!("t{tenant}"))?.to_vec();
        let desc = decode_kind_migrated(
            a.req("kind").map_err(|e| anyhow!("{e}"))?,
            tenant,
            &mut params,
            &mut spec,
        )?;
        anyhow::ensure!(
            params.len() == spec.size(),
            "fleet adapter for tenant {tenant}: {} params but spec expects {}",
            params.len(),
            spec.size()
        );
        tenants.push((
            tenant,
            AdapterEntry {
                desc,
                params: Arc::new(params),
                spec: Arc::new(spec),
            },
        ));
    }
    Ok((base, base_spec, tenants))
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    use crate::adapter::AdapterDesc;
    use crate::coordinator::merge::AdapterKind;

    /// A random adapter entry of each registered family (the four legacy
    /// kinds plus Monarch), with structurally valid (family-consistent)
    /// spec shapes.
    pub(crate) fn random_entry(rng: &mut Rng, which: usize) -> AdapterEntry {
        let layers = prop::size_in(rng, 1, 3);
        let names: Vec<String> = (0..layers).map(|i| format!("layer{i}.w")).collect();
        match which % 5 {
            0 | 3 => {
                let b = [2usize, 4][rng.below(2)];
                let r = prop::size_in(rng, 1, 4);
                let gsoft = which % 5 == 0;
                let entries = names
                    .iter()
                    .flat_map(|n| {
                        if gsoft {
                            vec![
                                (format!("{n}.gs_l"), vec![r, b, b]),
                                (format!("{n}.gs_r"), vec![r, b, b]),
                            ]
                        } else {
                            vec![(format!("{n}.oft_k"), vec![r, b, b])]
                        }
                    })
                    .collect();
                let spec = FlatSpec { entries };
                let params = rng.normal_vec(spec.size(), 0.4);
                AdapterEntry {
                    desc: if gsoft {
                        AdapterKind::Gsoft { block: b }.desc()
                    } else {
                        AdapterKind::Oft { block: b }.desc()
                    },
                    params: Arc::new(params),
                    spec: Arc::new(spec),
                }
            }
            1 => {
                let d = prop::size_in(rng, 2, 8);
                let rank = prop::size_in(rng, 1, d);
                let entries = names
                    .iter()
                    .flat_map(|n| {
                        vec![
                            (format!("{n}.lora_a"), vec![d, rank]),
                            (format!("{n}.lora_b"), vec![rank, d]),
                        ]
                    })
                    .collect();
                let spec = FlatSpec { entries };
                let params = rng.normal_vec(spec.size(), 0.1);
                AdapterEntry {
                    desc: AdapterKind::Lora.desc(),
                    params: Arc::new(params),
                    spec: Arc::new(spec),
                }
            }
            2 => {
                let groups = [1usize, 2][rng.below(2)];
                let c = groups * prop::size_in(rng, 1, 3);
                let k = [1usize, 3][rng.below(2)];
                let entries = names
                    .iter()
                    .map(|n| (format!("{n}.soc_k"), vec![c, c / groups, k, k]))
                    .collect();
                let spec = FlatSpec { entries };
                let params = rng.normal_vec(spec.size(), 0.05);
                AdapterEntry {
                    desc: AdapterKind::ConvGsSoc {
                        c,
                        k,
                        groups,
                        h: prop::size_in(rng, 1, 3),
                        w: prop::size_in(rng, 1, 3),
                        terms: prop::size_in(rng, 1, 8),
                    }
                    .desc(),
                    params: Arc::new(params),
                    spec: Arc::new(spec),
                }
            }
            _ => {
                // Monarch: an external family with no AdapterKind
                // variant — it must persist through the same generic
                // wire path.
                let b = [2usize, 3][rng.below(2)];
                let entries = names
                    .iter()
                    .flat_map(|n| {
                        vec![
                            (format!("{n}.mon_l"), vec![b, b, b]),
                            (format!("{n}.mon_r"), vec![b, b, b]),
                        ]
                    })
                    .collect();
                let spec = FlatSpec { entries };
                let params = rng.normal_vec(spec.size(), 0.4);
                AdapterEntry {
                    desc: AdapterDesc::new("monarch", &[("block", b)]).unwrap(),
                    params: Arc::new(params),
                    spec: Arc::new(spec),
                }
            }
        }
    }

    pub(crate) fn entries_equal(a: &AdapterEntry, b: &AdapterEntry) -> bool {
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        a.desc == b.desc && a.spec == b.spec && bits(&a.params) == bits(&b.params)
    }

    #[test]
    fn adapter_round_trip_is_identity_for_every_kind() {
        // Property (shrinking on params): encode → decode is the identity
        // for random adapters of every registered family (the four
        // legacy kinds plus Monarch), bit-for-bit.
        prop::check_shrunk(
            "GSAD adapter round-trip",
            901,
            32,
            |rng| {
                let which = rng.below(5);
                let entry = random_entry(rng, which);
                let tenant = rng.below(1 << 20) as TenantId;
                (
                    tenant,
                    entry.desc.clone(),
                    entry.spec.as_ref().clone(),
                    entry.params.as_ref().clone(),
                )
            },
            |(t, desc, spec, params)| {
                prop::shrink_vec_f32(params)
                    .into_iter()
                    .map(|p| (*t, desc.clone(), spec.clone(), p))
                    .collect()
            },
            |(tenant, desc, spec, params)| {
                let entry = AdapterEntry {
                    desc: desc.clone(),
                    params: Arc::new(params.clone()),
                    spec: Arc::new(spec.clone()),
                };
                let bytes = encode_adapter(*tenant, &entry);
                match decode(&bytes).expect("decode") {
                    Record::Adapter { tenant: t, entry: back } => {
                        assert_eq!(t, *tenant);
                        assert!(entries_equal(&entry, &back), "adapter drifted through GSAD");
                    }
                    _ => panic!("wrong record type"),
                }
            },
        );
    }

    #[test]
    fn merged_and_tombstone_round_trip() {
        let flat = vec![1.5f32, -2.0, 0.0, 3.25];
        let bytes = encode_merged(42, 0xDEAD_BEEF, &flat);
        match decode(&bytes).unwrap() {
            Record::Merged {
                tenant,
                params_crc,
                flat: back,
            } => {
                assert_eq!(tenant, 42);
                assert_eq!(params_crc, 0xDEAD_BEEF);
                assert_eq!(back, flat);
            }
            _ => panic!("wrong record type"),
        }
        match decode(&encode_tombstone(7)).unwrap() {
            Record::Tombstone { tenant } => assert_eq!(tenant, 7),
            _ => panic!("wrong record type"),
        }
    }

    /// Rewrite one substring of the JSON header region, adjusting the
    /// declared header length; the binary payload is untouched.
    fn with_patched_header(bytes: &[u8], from: &str, to: &str) -> Vec<u8> {
        let hlen = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as usize;
        let header = std::str::from_utf8(&bytes[8..8 + hlen]).unwrap();
        assert!(header.contains(from), "header lacks '{from}': {header}");
        let patched = header.replacen(from, to, 1);
        let mut out = bytes[..4].to_vec();
        out.extend_from_slice(&(patched.len() as u32).to_le_bytes());
        out.extend_from_slice(patched.as_bytes());
        out.extend_from_slice(&bytes[8 + hlen..]);
        out
    }

    #[test]
    fn unknown_version_and_record_type_are_rejected() {
        let mut rng = Rng::new(5);
        let entry = random_entry(&mut rng, 0);
        let bytes = encode_adapter(1, &entry);
        let flipped = with_patched_header(
            &bytes,
            &format!("\"v\":{VERSION}"),
            &format!("\"v\":{}", VERSION + 8),
        );
        assert!(decode(&flipped).is_err(), "future version must be rejected");
        let flipped = with_patched_header(&bytes, "\"record\":\"adapter\"", "\"record\":\"zzz\"");
        assert!(decode(&flipped).is_err(), "unknown record type must be rejected");
    }

    #[test]
    fn unregistered_family_tag_is_a_clean_error_not_a_panic() {
        // A record written by a build with an extra family must decode to
        // an "unknown adapter family" error here — both as a log record
        // and inside a fleet snapshot.
        let mut rng = Rng::new(6);
        let entry = random_entry(&mut rng, 0);
        let bytes = encode_adapter(3, &entry);
        let foreign = with_patched_header(&bytes, "\"kind\":\"gsoft\"", "\"kind\":\"butterfly\"");
        let err = decode(&foreign).expect_err("unknown family must not decode");
        assert!(
            format!("{err:#}").contains("unknown adapter family 'butterfly'"),
            "unexpected error: {err:#}"
        );

        let base_spec = FlatSpec {
            entries: vec![("layer0.w".into(), vec![4, 4])],
        };
        let base = BaseModel {
            weights: Arc::new(rng.normal_vec(base_spec.size(), 1.0)),
            spec: Arc::new(base_spec),
        };
        let fleet = encode_fleet(&base, &[(0, entry)]);
        let foreign = with_patched_header(&fleet, "\"kind\":\"gsoft\"", "\"kind\":\"butterfly\"");
        let err = decode_fleet(&foreign).expect_err("unknown family in a fleet");
        assert!(format!("{err:#}").contains("unknown adapter family 'butterfly'"));
    }

    #[test]
    fn migrate_hook_lets_a_bumped_family_read_its_v1_records() {
        // Satellite: a family that bumped its wire version to 2 must
        // still read the v1 records it persisted, routed through its
        // `migrate` hook — and a *future* v3 record must stay an error.
        use crate::adapter::{AdapterFamily, Config, FamilyRegistry, LayerOp, SlabCx};

        struct Relay2;
        impl AdapterFamily for Relay2 {
            fn tag(&self) -> &'static str {
                "relay2_test"
            }
            fn wire_version(&self) -> usize {
                2
            }
            fn suffixes(&self) -> &'static [&'static str] {
                &["r2_q"]
            }
            fn validate_slab(&self, _cfg: &Config, _cx: &SlabCx) -> Result<()> {
                Ok(())
            }
            fn synthetic_spec(
                &self,
                _cfg: &Config,
                _layers: &[String],
                _d: usize,
                _hint: usize,
            ) -> Result<FlatSpec> {
                Err(anyhow!("test-only family"))
            }
            fn merge(
                &self,
                _cfg: &Config,
                _base: &[f32],
                _adapter: &[f32],
                _base_spec: &FlatSpec,
                _adapter_spec: &FlatSpec,
            ) -> Result<Vec<f32>> {
                Err(anyhow!("test-only family"))
            }
            fn plan_layer(
                &self,
                _cfg: &Config,
                _params: &[f32],
                _spec: &FlatSpec,
                _layer: &str,
                _d: usize,
            ) -> Result<Option<Box<dyn LayerOp>>> {
                Ok(None)
            }
            // v1 stored the slab in reverse element order.
            fn migrate(
                &self,
                _cfg: &Config,
                old_fv: usize,
                params: &mut Vec<f32>,
                _spec: &mut FlatSpec,
            ) -> Result<()> {
                anyhow::ensure!(old_fv == 1, "only v1 records are migratable");
                params.reverse();
                Ok(())
            }
        }
        static RELAY2: Relay2 = Relay2;
        FamilyRegistry::register(&RELAY2).unwrap();

        let spec = FlatSpec {
            entries: vec![("layer0.w.r2_q".into(), vec![2, 2])],
        };
        let params = vec![1.0f32, 2.0, 3.0, 4.0];
        let entry = AdapterEntry {
            desc: crate::adapter::AdapterDesc::new("relay2_test", &[]).unwrap(),
            params: Arc::new(params.clone()),
            spec: Arc::new(spec),
        };
        let bytes = encode_adapter(9, &entry); // header carries "fv":2

        // Current-version record: decodes untouched, migrate not called.
        match decode(&bytes).unwrap() {
            Record::Adapter { entry: back, .. } => {
                assert_eq!(back.params.as_ref(), &params)
            }
            _ => panic!("wrong record type"),
        }

        // v1 record: decodes through the migrate hook (reversed slab).
        let v1 = with_patched_header(&bytes, "\"fv\":2", "\"fv\":1");
        match decode(&v1).unwrap() {
            Record::Adapter { tenant, entry: back } => {
                assert_eq!(tenant, 9);
                assert_eq!(back.desc.tag(), "relay2_test");
                let want: Vec<f32> = params.iter().rev().copied().collect();
                assert_eq!(back.params.as_ref(), &want, "migrate hook did not run");
            }
            _ => panic!("wrong record type"),
        }

        // Future record: still a clean error.
        let v3 = with_patched_header(&bytes, "\"fv\":2", "\"fv\":3");
        let err = decode(&v3).expect_err("future family version must be rejected");
        assert!(
            format!("{err:#}").contains("reads up to v2"),
            "unexpected error: {err:#}"
        );

        // A version the hook itself refuses surfaces as a decode error
        // (not a panic, not a silent wrong slab).
        let v0 = with_patched_header(&bytes, "\"fv\":2", "\"fv\":0");
        let err = decode(&v0).expect_err("hook-refused version must error");
        assert!(
            format!("{err:#}").contains("only v1 records are migratable"),
            "unexpected error: {err:#}"
        );

        // A bumped family *without* a migrate override fails loudly via
        // the default hook (called directly; never registered).
        struct NoPath;
        impl AdapterFamily for NoPath {
            fn tag(&self) -> &'static str {
                "nopath_test"
            }
            fn wire_version(&self) -> usize {
                2
            }
            fn suffixes(&self) -> &'static [&'static str] {
                &["np_q"]
            }
            fn validate_slab(&self, _cfg: &Config, _cx: &SlabCx) -> Result<()> {
                Ok(())
            }
            fn synthetic_spec(
                &self,
                _cfg: &Config,
                _layers: &[String],
                _d: usize,
                _hint: usize,
            ) -> Result<FlatSpec> {
                Err(anyhow!("test-only family"))
            }
            fn merge(
                &self,
                _cfg: &Config,
                _base: &[f32],
                _adapter: &[f32],
                _base_spec: &FlatSpec,
                _adapter_spec: &FlatSpec,
            ) -> Result<Vec<f32>> {
                Err(anyhow!("test-only family"))
            }
            fn plan_layer(
                &self,
                _cfg: &Config,
                _params: &[f32],
                _spec: &FlatSpec,
                _layer: &str,
                _d: usize,
            ) -> Result<Option<Box<dyn LayerOp>>> {
                Ok(None)
            }
        }
        let cfg = AdapterKind::Lora.desc().cfg().clone();
        let mut p = vec![0.0f32];
        let mut s = FlatSpec { entries: vec![] };
        let err = NoPath
            .migrate(&cfg, 1, &mut p, &mut s)
            .expect_err("default migrate must decline");
        assert!(
            format!("{err:#}").contains("no migration path from wire version 1 to v2"),
            "unexpected error: {err:#}"
        );
    }

    #[test]
    fn wire_form_is_byte_identical_to_the_legacy_enum_encoding() {
        // The generic family encoder must reproduce the exact v1 header
        // bytes the closed-enum encoder wrote (JSON objects serialize
        // with sorted keys), so stores written before the trait refactor
        // replay unchanged. Pin the `"kind"` object per family.
        let cases: &[(AdapterDesc, &str)] = &[
            (
                AdapterKind::Gsoft { block: 2 }.desc(),
                r#"{"block":2,"kind":"gsoft"}"#,
            ),
            (
                AdapterKind::Oft { block: 4 }.desc(),
                r#"{"block":4,"kind":"oft"}"#,
            ),
            (AdapterKind::Lora.desc(), r#"{"kind":"lora"}"#),
            (
                AdapterKind::ConvGsSoc {
                    c: 4,
                    k: 3,
                    groups: 2,
                    h: 2,
                    w: 3,
                    terms: 8,
                }
                .desc(),
                r#"{"c":4,"groups":2,"h":2,"k":3,"kind":"conv_gssoc","terms":8,"w":3}"#,
            ),
            (
                AdapterDesc::new("monarch", &[("block", 3)]).unwrap(),
                r#"{"block":3,"kind":"monarch"}"#,
            ),
        ];
        for (desc, want) in cases {
            assert_eq!(
                crate::adapter::desc_to_json(desc).to_string(),
                *want,
                "wire form drifted for family '{}'",
                desc.tag()
            );
            let back = crate::adapter::desc_from_json(
                &Json::parse(want).expect("pinned wire form parses"),
            )
            .expect("pinned wire form decodes");
            assert_eq!(&back, desc, "decode must invert encode");
        }
    }

    #[test]
    fn fleet_round_trip() {
        let mut rng = Rng::new(9);
        let base_spec = FlatSpec {
            entries: vec![("layer0.w".into(), vec![4, 4]), ("head".into(), vec![4, 2])],
        };
        let base = BaseModel {
            weights: Arc::new(rng.normal_vec(base_spec.size(), 1.0)),
            spec: Arc::new(base_spec),
        };
        let tenants: Vec<(TenantId, AdapterEntry)> = (0..5)
            .map(|t| (t as TenantId, random_entry(&mut rng, t)))
            .collect();
        let bytes = encode_fleet(&base, &tenants);
        let (bw, bs, back) = decode_fleet(&bytes).unwrap();
        assert_eq!(&bw, base.weights.as_ref());
        assert_eq!(&bs, base.spec.as_ref());
        assert_eq!(back.len(), tenants.len());
        for ((t0, e0), (t1, e1)) in tenants.iter().zip(back.iter()) {
            assert_eq!(t0, t1);
            assert!(entries_equal(e0, e1));
        }
        // A single-tenant record is not a fleet.
        assert!(decode_fleet(&encode_tombstone(0)).is_err());
    }
}
