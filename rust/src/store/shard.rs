//! Sharded segment logs: the factor tier at fleet scale (DESIGN.md §13).
//!
//! One [`SegmentLog`] behind one mutex caps registration throughput at a
//! single fsync stream and makes every torn tail a fleet-wide event. The
//! [`ShardedLog`] partitions records by tenant hash across N independent
//! segment logs (`shard{i}.log` under the store directory), each behind
//! its own append mutex:
//!
//! - **appends to different shards run in parallel** — N concurrent fsync
//!   streams, so registration throughput scales with shard count until
//!   the disk saturates;
//! - **boot replay is parallel** (`util::pool::parallel_map` over the
//!   shard files), so cold-open latency is the slowest shard, not the sum;
//! - **torn-tail recovery is per-shard**: a crash mid-append corrupts at
//!   most the tail of one shard, and that shard recovers its own prefix
//!   while the other N−1 come up untouched — one corrupt shard never
//!   blocks the fleet.
//!
//! The tenant→shard map is a fixed [SplitMix64] finalizer over the tenant
//! id, so it is stable across processes, platforms and reopens; the shard
//! *count* is inferred from the files on disk at open (the requested
//! count only seeds a fresh directory), so a directory can never be
//! reopened under a different partitioning than it was written with. A
//! legacy single-file `adapters.log` found at open is folded into the
//! shards once and removed — old store directories upgrade in place.
//!
//! [SplitMix64]: https://prng.di.unimi.it/splitmix64.c

use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::serve::registry::TenantId;
use crate::util::pool::{default_workers, parallel_map};

use super::log::{sync_dir, LogOpts, LogStats, SegmentLog};

/// Shard count used when a fresh store directory is opened without an
/// explicit request (`gsoft ... --shards N` overrides it).
pub const DEFAULT_SHARDS: usize = 4;

/// Stable tenant→shard map: a SplitMix64 finalizer, so the partitioning
/// is a pure function of the tenant id — identical across runs, builds
/// and platforms (replay depends on it).
pub fn shard_of(tenant: TenantId, shards: usize) -> usize {
    debug_assert!(shards > 0);
    let mut x = tenant.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    (x % shards as u64) as usize
}

fn shard_file(i: usize) -> String {
    format!("shard{i}.log")
}

/// N independent segment logs partitioned by tenant hash. All methods
/// take `&self`: each shard guards itself, so appends to different
/// shards never contend.
pub struct ShardedLog {
    dir: PathBuf,
    shards: Vec<Mutex<SegmentLog>>,
}

impl ShardedLog {
    /// Open (creating if needed) the sharded log under `dir`.
    ///
    /// `requested_shards` applies only when the directory holds no shard
    /// files yet; an existing layout always wins, because the on-disk
    /// partitioning must match the hash that wrote it. A legacy
    /// `adapters.log` (single-log layout) is migrated into the shards
    /// and removed.
    pub fn open(dir: impl AsRef<Path>, requested_shards: usize, opts: LogOpts) -> Result<ShardedLog> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating store dir {}", dir.display()))?;
        let n = match Self::detect_shards(&dir)? {
            Some(existing) => existing,
            None => requested_shards.max(1),
        };

        // Parallel replay: each shard recovers (and truncates) its own
        // torn tail independently; only real I/O errors propagate.
        let opened: Vec<Result<SegmentLog>> = parallel_map(n, default_workers(), |i| {
            let t0 = crate::obs::enabled().then(Instant::now);
            let log = SegmentLog::open(dir.join(shard_file(i)), opts)?;
            if let Some(t0) = t0 {
                let store = crate::obs::store();
                store.record_shard_replay(t0.elapsed());
                if log.stats().truncated_tail_bytes > 0 {
                    store.record_shard_torn_tail();
                }
            }
            Ok(log)
        });
        let mut shards = Vec::with_capacity(n);
        for (i, log) in opened.into_iter().enumerate() {
            shards.push(Mutex::new(
                log.with_context(|| format!("replaying shard {i} of {}", dir.display()))?,
            ));
        }
        if crate::obs::enabled() {
            crate::obs::store().set_shard_count(n);
        }
        let sharded = ShardedLog { dir, shards };
        sharded.migrate_legacy(opts)?;
        Ok(sharded)
    }

    /// Shard count already on disk, if any (`None` for a fresh directory).
    fn detect_shards(dir: &Path) -> Result<Option<usize>> {
        let mut max_idx: Option<usize> = None;
        for e in std::fs::read_dir(dir)? {
            let name = e?.file_name();
            let Some(name) = name.to_str() else { continue };
            if let Some(i) = name
                .strip_prefix("shard")
                .and_then(|s| s.strip_suffix(".log"))
                .and_then(|s| s.parse::<usize>().ok())
            {
                max_idx = Some(max_idx.map_or(i, |m: usize| m.max(i)));
            }
        }
        Ok(max_idx.map(|m| m + 1))
    }

    /// Fold a pre-sharding `adapters.log` into the shards, then remove it.
    ///
    /// Idempotent across crashes: every folded record is synced before
    /// the legacy file is unlinked, and a rerun (crash before the unlink)
    /// skips tenants the shards already hold — so a shard record can
    /// never be rolled back to an older legacy version.
    fn migrate_legacy(&self, opts: LogOpts) -> Result<()> {
        let legacy = self.dir.join(super::LOG_FILE);
        if !legacy.exists() {
            return Ok(());
        }
        let mut old = SegmentLog::open(&legacy, opts)
            .with_context(|| format!("replaying legacy log {}", legacy.display()))?;
        for tenant in old.tenant_ids() {
            let shard = &self.shards[self.shard_index(tenant)];
            let mut shard = shard.lock().unwrap();
            if shard.contains(tenant) {
                continue; // already folded by an interrupted migration
            }
            let payload = old
                .get(tenant)?
                .expect("legacy log index points at a vanished record");
            shard.append(tenant, &payload)?;
        }
        drop(old);
        std::fs::remove_file(&legacy)
            .with_context(|| format!("removing migrated legacy log {}", legacy.display()))?;
        sync_dir(&legacy)?;
        Ok(())
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shard_index(&self, tenant: TenantId) -> usize {
        shard_of(tenant, self.shards.len())
    }

    /// Append (or overwrite) a tenant's adapter record — holds only that
    /// tenant's shard lock.
    pub fn append(&self, tenant: TenantId, payload: &[u8]) -> Result<()> {
        let r = self.shards[self.shard_index(tenant)]
            .lock()
            .unwrap()
            .append(tenant, payload);
        if r.is_ok() && crate::obs::enabled() {
            crate::obs::store().record_shard_append();
        }
        r
    }

    /// Tombstone a tenant. Returns `false` if it was not live.
    pub fn delete(&self, tenant: TenantId) -> Result<bool> {
        self.shards[self.shard_index(tenant)]
            .lock()
            .unwrap()
            .delete(tenant)
    }

    /// Read a tenant's latest record payload (CRC re-verified).
    pub fn get(&self, tenant: TenantId) -> Result<Option<Vec<u8>>> {
        self.shards[self.shard_index(tenant)]
            .lock()
            .unwrap()
            .get(tenant)
    }

    pub fn contains(&self, tenant: TenantId) -> bool {
        self.shards[self.shard_index(tenant)]
            .lock()
            .unwrap()
            .contains(tenant)
    }

    /// Live tenants fleet-wide (each tenant lives in exactly one shard).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn tenant_ids(&self) -> Vec<TenantId> {
        let mut ids: Vec<TenantId> = self
            .shards
            .iter()
            .flat_map(|s| s.lock().unwrap().tenant_ids())
            .collect();
        ids.sort_unstable();
        ids
    }

    pub fn file_bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.lock().unwrap().file_bytes()).sum()
    }

    /// Fleet-wide garbage fraction (byte-weighted across shards).
    pub fn garbage_ratio(&self) -> f64 {
        let (mut file, mut live) = (0u64, 0u64);
        for s in &self.shards {
            let s = s.lock().unwrap();
            file += s.file_bytes();
            live += s.live_bytes();
        }
        if file == 0 {
            0.0
        } else {
            1.0 - live as f64 / file as f64
        }
    }

    /// Aggregated monotonic counters across all shards.
    pub fn stats(&self) -> LogStats {
        let mut total = LogStats::default();
        for s in &self.shards {
            let st = s.lock().unwrap().stats();
            total.appends += st.appends;
            total.deletes += st.deletes;
            total.compactions += st.compactions;
            total.truncated_tail_bytes += st.truncated_tail_bytes;
        }
        total
    }

    /// Toggle inline compaction on every shard's append path. The
    /// maintenance thread flips this off while it owns compaction and
    /// back on at shutdown, so an unmaintained store stays bounded.
    pub fn set_auto_compact(&self, on: bool) {
        for s in &self.shards {
            s.lock().unwrap().set_auto_compact(on);
        }
    }

    /// Shards whose garbage ratio is past their compaction policy — the
    /// maintenance thread's scan. Only reads per-shard counters; holds
    /// each shard lock briefly.
    pub fn shards_wanting_compaction(&self) -> Vec<usize> {
        (0..self.shards.len())
            .filter(|&i| self.shards[i].lock().unwrap().wants_compaction())
            .collect()
    }

    /// Compact one shard (under that shard's lock only — the other
    /// shards keep serving appends throughout).
    pub fn compact_shard(&self, i: usize) -> Result<()> {
        self.shards[i].lock().unwrap().compact()
    }

    /// Force-compact every shard (tests / explicit `AdapterStore::compact`).
    pub fn compact_all(&self) -> Result<()> {
        for i in 0..self.shards.len() {
            self.compact_shard(i)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::gsad;
    use crate::store::gsad::tests::random_entry;
    use crate::util::prop;
    use crate::util::tmp::unique_temp_dir;
    use std::collections::HashMap;

    fn no_compact() -> LogOpts {
        LogOpts {
            garbage_threshold: 1.1,
            min_compact_bytes: u64::MAX,
        }
    }

    #[test]
    fn partitions_are_stable_and_cover_all_shards() {
        // The hash is pinned by on-disk state: if this mapping ever
        // changes, existing sharded directories replay records into the
        // wrong shards.
        for &n in &[1usize, 2, 4, 16] {
            let mut seen = vec![false; n];
            for t in 0..512u64 {
                let s = shard_of(t, n);
                assert!(s < n);
                assert_eq!(s, shard_of(t, n), "hash must be deterministic");
                seen[s] = true;
            }
            assert!(seen.iter().all(|&b| b), "512 tenants must cover {n} shards");
        }
    }

    #[test]
    fn sharded_round_trip_and_reopen_infers_the_shard_count() {
        let dir = unique_temp_dir("shard_basic");
        let mut rng = crate::util::rng::Rng::new(51);
        let entries: Vec<_> = (0..12).map(|i| random_entry(&mut rng, i)).collect();
        {
            let log = ShardedLog::open(&dir, 4, LogOpts::default()).unwrap();
            assert_eq!(log.num_shards(), 4);
            for (t, e) in entries.iter().enumerate() {
                log.append(t as TenantId, &gsad::encode_adapter(t as TenantId, e))
                    .unwrap();
            }
            assert!(log.delete(3).unwrap());
            assert_eq!(log.len(), 11);
        }
        // Reopen with a *different* requested count: the on-disk layout
        // must win, or records would hash to the wrong shard.
        let log = ShardedLog::open(&dir, 16, LogOpts::default()).unwrap();
        assert_eq!(log.num_shards(), 4, "existing layout overrides the request");
        let want: Vec<TenantId> = (0..12u64).filter(|&t| t != 3).collect();
        assert_eq!(log.tenant_ids(), want);
        for &t in &want {
            let payload = log.get(t).unwrap().expect("tenant survives reopen");
            match gsad::decode(&payload).unwrap() {
                gsad::Record::Adapter { tenant, .. } => assert_eq!(tenant, t),
                _ => panic!("wrong record"),
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn legacy_single_log_migrates_in_place() {
        let dir = unique_temp_dir("shard_migrate");
        let mut rng = crate::util::rng::Rng::new(52);
        let entries: Vec<_> = (0..6).map(|i| random_entry(&mut rng, i)).collect();
        // Write a pre-sharding store: one adapters.log.
        {
            let mut old = SegmentLog::open(dir.join(crate::store::LOG_FILE), LogOpts::default())
                .unwrap();
            for (t, e) in entries.iter().enumerate() {
                old.append(t as TenantId, &gsad::encode_adapter(t as TenantId, e))
                    .unwrap();
            }
        }
        let log = ShardedLog::open(&dir, 3, LogOpts::default()).unwrap();
        assert_eq!(log.len(), 6, "every legacy tenant migrates");
        assert!(
            !dir.join(crate::store::LOG_FILE).exists(),
            "legacy log is removed after migration"
        );
        // A post-migration overwrite must not be rolled back by a rerun
        // of the migration path (simulated crash: legacy file reappears).
        let updated = random_entry(&mut rng, 9);
        let updated_payload = gsad::encode_adapter(2, &updated);
        log.append(2, &updated_payload).unwrap();
        drop(log);
        let log = ShardedLog::open(&dir, 3, LogOpts::default()).unwrap();
        assert_eq!(log.get(2).unwrap().unwrap(), updated_payload);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Random op sequence × shard count × kill point for the
    /// sharded-vs-single replay equivalence property.
    #[derive(Debug, Clone)]
    struct ShardCase {
        shards: usize,
        ops: Vec<(TenantId, bool)>, // (tenant, is_delete)
        /// Kill point: how many ops actually land before the "crash".
        applied: usize,
        /// Which (applied) op's shard gets its tail torn, scaled 0..=1000
        /// into the ops that landed.
        tear_millis: usize,
    }

    fn shrink_shard(c: &ShardCase) -> Vec<ShardCase> {
        let mut out = Vec::new();
        if c.shards > 1 {
            out.push(ShardCase {
                shards: c.shards / 2,
                ..c.clone()
            });
        }
        if !c.ops.is_empty() {
            out.push(ShardCase {
                ops: c.ops[..c.ops.len() / 2].to_vec(),
                applied: c.applied.min(c.ops.len() / 2),
                ..c.clone()
            });
        }
        for applied in prop::shrink_usize(c.applied, 0) {
            out.push(ShardCase {
                applied,
                ..c.clone()
            });
        }
        for tear in prop::shrink_usize(c.tear_millis, 0) {
            out.push(ShardCase {
                tear_millis: tear,
                ..c.clone()
            });
        }
        out
    }

    #[test]
    fn sharded_replay_equals_single_log_replay() {
        // Property (shrinking): apply the same op sequence to a sharded
        // log and a single log, kill both after `applied` ops, then tear
        // the tail of exactly one shard — the sharded replay must equal
        // the single-log replay minus at most the torn shard's own
        // un-acknowledged suffix, and every *other* shard must come up
        // complete (one corrupt shard never blocks the fleet).
        prop::check_shrunk(
            "sharded replay ≡ single-log replay",
            910,
            24,
            |rng| {
                let ops: Vec<(TenantId, bool)> = (0..prop::size_in(rng, 1, 16))
                    .map(|_| (rng.below(6) as TenantId, rng.below(4) == 0))
                    .collect();
                let applied = rng.below(ops.len() + 1);
                ShardCase {
                    shards: [1, 2, 4, 16][rng.below(4)],
                    ops,
                    applied,
                    tear_millis: rng.below(1001),
                }
            },
            shrink_shard,
            |case| {
                let dir = unique_temp_dir("shard_prop");
                let mut rng = crate::util::rng::Rng::new(78);
                let sharded = ShardedLog::open(dir.join("sharded"), case.shards, no_compact())
                    .unwrap();
                let mut single =
                    SegmentLog::open(dir.join("single/adapters.log"), no_compact()).unwrap();
                // Reference live view after the kill point.
                let mut expect: HashMap<TenantId, Vec<u8>> = HashMap::new();
                for &(tenant, is_delete) in &case.ops[..case.applied] {
                    if is_delete {
                        sharded.delete(tenant).unwrap();
                        single.delete(tenant).unwrap();
                        expect.remove(&tenant);
                    } else {
                        let e = random_entry(&mut rng, tenant as usize);
                        let payload = gsad::encode_adapter(tenant, &e);
                        sharded.append(tenant, &payload).unwrap();
                        single.append(tenant, &payload).unwrap();
                        expect.insert(tenant, payload);
                    }
                }
                drop(single);
                // Tear the tail of exactly one shard: cut its file at a
                // byte chosen inside the last record, so that shard loses
                // its most recent op (and only that).
                let torn_shard = case.tear_millis % case.shards;
                let torn_path = dir.join("sharded").join(shard_file(torn_shard));
                let bytes = std::fs::read(&torn_path).unwrap();
                drop(sharded);
                if !bytes.is_empty() {
                    // Cutting mid-file can only lose a suffix of *that
                    // shard's* ops (per-shard order is a subsequence of
                    // the global op order).
                    let cut = bytes.len() - 1 - (case.tear_millis * (bytes.len() - 1) / 1000);
                    std::fs::write(&torn_path, &bytes[..cut]).unwrap();
                }

                let sharded =
                    ShardedLog::open(dir.join("sharded"), case.shards, no_compact()).unwrap();
                for (&tenant, payload) in &expect {
                    if shard_of(tenant, case.shards) == torn_shard {
                        // The torn shard recovered *some* prefix of its
                        // own history: the tenant either reads back its
                        // exact acknowledged payload or an older one, or
                        // is gone — but never garbage.
                        if let Some(got) = sharded.get(tenant).unwrap() {
                            gsad::decode(&got).expect("recovered record must decode");
                        }
                    } else {
                        // Every untorn shard must equal the single-log
                        // replay exactly.
                        assert_eq!(
                            sharded.get(tenant).unwrap().as_deref(),
                            Some(payload.as_slice()),
                            "tenant {tenant} (untorn shard) diverged from the single log"
                        );
                    }
                }
                // No tenant outside the torn shard may have vanished.
                let single = SegmentLog::open(dir.join("single/adapters.log"), no_compact())
                    .unwrap();
                for t in single.tenant_ids() {
                    if shard_of(t, case.shards) != torn_shard {
                        assert!(
                            sharded.contains(t),
                            "tenant {t} lost outside the torn shard"
                        );
                    }
                }
                // The fleet keeps serving: an append to every shard works.
                for t in 0..case.shards as TenantId {
                    let e = random_entry(&mut rng, 99);
                    sharded.append(1000 + t, &gsad::encode_adapter(1000 + t, &e)).unwrap();
                }
                let _ = std::fs::remove_dir_all(&dir);
            },
        );
    }

    #[test]
    fn per_shard_compaction_leaves_other_shards_untouched() {
        let dir = unique_temp_dir("shard_compact");
        let mut rng = crate::util::rng::Rng::new(53);
        let log = ShardedLog::open(
            &dir,
            4,
            LogOpts {
                garbage_threshold: 0.5,
                min_compact_bytes: 0,
            },
        )
        .unwrap();
        log.set_auto_compact(false);
        // Overwrite one tenant many times: exactly its shard accumulates
        // garbage and shows up in the maintenance scan.
        let e = random_entry(&mut rng, 0);
        let payload = gsad::encode_adapter(7, &e);
        for _ in 0..8 {
            log.append(7, &payload).unwrap();
        }
        let dirty = log.shards_wanting_compaction();
        assert_eq!(dirty, vec![log.shard_index(7)]);
        log.compact_shard(dirty[0]).unwrap();
        assert!(log.shards_wanting_compaction().is_empty());
        assert_eq!(log.stats().compactions, 1);
        assert_eq!(log.get(7).unwrap().unwrap(), payload);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
