//! Append-only segment log: the durable factor tier's storage engine.
//!
//! One file of length-prefixed records, each `[u32 len][u32 crc][payload]`
//! where the payload is a `GSAD` record ([`super::gsad`]) — an adapter
//! registration/update or a tombstone delete. An in-memory index maps
//! live tenants to their latest record's byte span; everything else in
//! the file is garbage that compaction reclaims.
//!
//! Durability model:
//! - every append is flushed (`sync_all`) before it is indexed, so an
//!   acknowledged registration survives a crash;
//! - replay scans from the start and stops at the first record whose
//!   length prefix, CRC, or payload does not fully check out — a torn
//!   tail from a mid-write crash loses exactly the unacknowledged suffix,
//!   never an acknowledged prefix. The file is truncated back to the
//!   recovered prefix so later appends extend a clean log;
//! - compaction is synchronous and atomic: live records are rewritten to
//!   a sibling file which is renamed over the log (rename is atomic on
//!   POSIX), triggered once the garbage ratio passes
//!   [`LogOpts::garbage_threshold`] past [`LogOpts::min_compact_bytes`].

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::serve::registry::TenantId;
use crate::util::container::crc32;

use super::gsad;

/// Compaction policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct LogOpts {
    /// Compact when `1 - live_bytes/file_bytes` exceeds this.
    pub garbage_threshold: f64,
    /// ...but never bother below this file size.
    pub min_compact_bytes: u64,
}

impl Default for LogOpts {
    fn default() -> Self {
        LogOpts {
            garbage_threshold: 0.5,
            min_compact_bytes: 64 << 10,
        }
    }
}

/// Monotonic counters (snapshot with [`SegmentLog::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LogStats {
    pub appends: u64,
    pub deletes: u64,
    pub compactions: u64,
    /// Bytes dropped by replay because the tail record was torn.
    pub truncated_tail_bytes: u64,
}

#[derive(Clone, Copy, Debug)]
struct Span {
    /// Offset of the record header (the `[len][crc]` pair).
    off: u64,
    /// Payload length in bytes.
    len: u32,
}

/// The append-only segment log with its in-memory offset index.
pub struct SegmentLog {
    path: PathBuf,
    file: File,
    index: HashMap<TenantId, Span>,
    file_bytes: u64,
    live_bytes: u64,
    opts: LogOpts,
    stats: LogStats,
    /// When `false`, appends/deletes never compact inline — a background
    /// maintainer owns compaction instead ([`super::maint::Maintainer`]
    /// polls [`SegmentLog::wants_compaction`] and calls
    /// [`SegmentLog::compact`] off the request path).
    auto_compact: bool,
}

const RECORD_HEADER: u64 = 8;
/// Cap on a single record (a paranoia bound against a corrupt length
/// prefix mid-file masquerading as a multi-GiB record); enforced on the
/// write path too, so no acknowledged record can trip it on replay.
const MAX_RECORD_BYTES: u32 = 1 << 30;

/// Flush a directory entry (file creation / rename) to disk — `sync_all`
/// on the file alone does not make the *name* durable across power loss.
pub(crate) fn sync_dir(path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            File::open(dir)
                .and_then(|d| d.sync_all())
                .with_context(|| format!("syncing directory {}", dir.display()))?;
        }
    }
    Ok(())
}

impl SegmentLog {
    /// Open (creating if absent) and replay the log at `path`.
    pub fn open(path: impl AsRef<Path>, opts: LogOpts) -> Result<SegmentLog> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let preexisting = path.exists();
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(&path)
            .with_context(|| format!("opening segment log {}", path.display()))?;
        if !preexisting {
            // A freshly created log whose directory entry is not flushed
            // can vanish on power loss even after synced appends.
            sync_dir(&path)?;
        }

        // Replay: scan records, keep the last live span per tenant.
        let mut bytes = Vec::new();
        file.read_to_end(&mut bytes)?;
        let mut index: HashMap<TenantId, Span> = HashMap::new();
        let mut off = 0usize;
        let mut stats = LogStats::default();
        while off + RECORD_HEADER as usize <= bytes.len() {
            let len = u32::from_le_bytes([bytes[off], bytes[off + 1], bytes[off + 2], bytes[off + 3]]);
            let want_crc =
                u32::from_le_bytes([bytes[off + 4], bytes[off + 5], bytes[off + 6], bytes[off + 7]]);
            let start = off + RECORD_HEADER as usize;
            let end = match (len <= MAX_RECORD_BYTES).then(|| start.checked_add(len as usize)).flatten() {
                Some(e) if e <= bytes.len() => e,
                _ => break, // torn length prefix / truncated payload
            };
            let payload = &bytes[start..end];
            if crc32(payload) != want_crc {
                break; // torn or corrupt record: recover the prefix only
            }
            match gsad::decode(payload) {
                Ok(gsad::Record::Adapter { tenant, .. }) => {
                    index.insert(
                        tenant,
                        Span {
                            off: off as u64,
                            len,
                        },
                    );
                }
                Ok(gsad::Record::Tombstone { tenant }) => {
                    index.remove(&tenant);
                }
                // Merged records never appear in the adapter log; a
                // payload that fails GSAD decode despite a good CRC is a
                // format error — stop and recover the prefix.
                _ => break,
            }
            off = end;
        }
        if off < bytes.len() {
            stats.truncated_tail_bytes = (bytes.len() - off) as u64;
            file.set_len(off as u64)?;
            file.sync_all()?;
        }
        let live_bytes = index
            .values()
            .map(|s| RECORD_HEADER + s.len as u64)
            .sum();
        Ok(SegmentLog {
            path,
            file,
            index,
            file_bytes: off as u64,
            live_bytes,
            opts,
            stats,
            auto_compact: true,
        })
    }

    /// Toggle inline compaction on the append/delete path. Off means the
    /// caller promises some other actor (the maintenance thread) watches
    /// [`SegmentLog::wants_compaction`] — garbage accumulates unboundedly
    /// otherwise.
    pub fn set_auto_compact(&mut self, on: bool) {
        self.auto_compact = on;
    }

    /// Would [`LogOpts`] trigger a compaction right now? (The predicate
    /// behind inline auto-compaction, exposed so an external maintainer
    /// can apply the same policy off the request path.)
    pub fn wants_compaction(&self) -> bool {
        self.file_bytes > self.opts.min_compact_bytes
            && self.garbage_ratio() > self.opts.garbage_threshold
    }

    fn write_record(&mut self, payload: &[u8]) -> Result<Span> {
        // Replay treats anything over MAX_RECORD_BYTES as a torn length
        // prefix, so accepting it here would ack a write that the next
        // reopen silently discards (along with everything after it).
        anyhow::ensure!(
            payload.len() <= MAX_RECORD_BYTES as usize,
            "segment log record of {} bytes exceeds the {} byte cap",
            payload.len(),
            MAX_RECORD_BYTES
        );
        let span = Span {
            off: self.file_bytes,
            len: payload.len() as u32,
        };
        let mut rec = Vec::with_capacity(RECORD_HEADER as usize + payload.len());
        rec.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        rec.extend_from_slice(&crc32(payload).to_le_bytes());
        rec.extend_from_slice(payload);
        let timed = crate::obs::enabled();
        let t0 = timed.then(Instant::now);
        self.file.seek(SeekFrom::Start(self.file_bytes))?;
        self.file.write_all(&rec)?;
        let t1 = timed.then(Instant::now);
        self.file.sync_all()?;
        if let (Some(t0), Some(t1)) = (t0, t1) {
            let store = crate::obs::store();
            store.record_append(t1.duration_since(t0));
            store.record_fsync(t1.elapsed());
        }
        self.file_bytes += rec.len() as u64;
        Ok(span)
    }

    /// Append (or overwrite) a tenant's adapter record. The payload must
    /// be a `GSAD` adapter record for `tenant` — replay trusts that
    /// correspondence.
    pub fn append(&mut self, tenant: TenantId, payload: &[u8]) -> Result<()> {
        let span = self.write_record(payload)?;
        if let Some(old) = self.index.insert(tenant, span) {
            self.live_bytes -= RECORD_HEADER + old.len as u64;
        }
        self.live_bytes += RECORD_HEADER + span.len as u64;
        self.stats.appends += 1;
        self.maybe_compact()?;
        Ok(())
    }

    /// Tombstone a tenant. Returns `false` if it was not live.
    pub fn delete(&mut self, tenant: TenantId) -> Result<bool> {
        if !self.index.contains_key(&tenant) {
            return Ok(false);
        }
        self.write_record(&gsad::encode_tombstone(tenant))?;
        if let Some(old) = self.index.remove(&tenant) {
            self.live_bytes -= RECORD_HEADER + old.len as u64;
        }
        self.stats.deletes += 1;
        self.maybe_compact()?;
        Ok(true)
    }

    /// Read a tenant's latest record payload (CRC re-verified).
    pub fn get(&mut self, tenant: TenantId) -> Result<Option<Vec<u8>>> {
        let Some(span) = self.index.get(&tenant).copied() else {
            return Ok(None);
        };
        self.file.seek(SeekFrom::Start(span.off))?;
        let mut header = [0u8; RECORD_HEADER as usize];
        self.file.read_exact(&mut header)?;
        let len = u32::from_le_bytes([header[0], header[1], header[2], header[3]]);
        let want_crc = u32::from_le_bytes([header[4], header[5], header[6], header[7]]);
        anyhow::ensure!(
            len == span.len,
            "segment log record for tenant {tenant} changed length underfoot"
        );
        let mut payload = vec![0u8; len as usize];
        self.file.read_exact(&mut payload)?;
        anyhow::ensure!(
            crc32(&payload) == want_crc,
            "segment log record for tenant {tenant} failed its CRC32 check"
        );
        Ok(Some(payload))
    }

    pub fn contains(&self, tenant: TenantId) -> bool {
        self.index.contains_key(&tenant)
    }

    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    pub fn tenant_ids(&self) -> Vec<TenantId> {
        let mut ids: Vec<TenantId> = self.index.keys().copied().collect();
        ids.sort_unstable();
        ids
    }

    pub fn file_bytes(&self) -> u64 {
        self.file_bytes
    }

    pub fn live_bytes(&self) -> u64 {
        self.live_bytes
    }

    /// Fraction of the file occupied by superseded records and tombstones.
    pub fn garbage_ratio(&self) -> f64 {
        if self.file_bytes == 0 {
            0.0
        } else {
            1.0 - self.live_bytes as f64 / self.file_bytes as f64
        }
    }

    pub fn stats(&self) -> LogStats {
        self.stats
    }

    fn maybe_compact(&mut self) -> Result<()> {
        if self.auto_compact && self.wants_compaction() {
            self.compact()?;
        }
        Ok(())
    }

    /// Rewrite live records into a fresh segment and atomically rename it
    /// over the log. Synchronous — callers pay it inline (the trigger
    /// ratio bounds the amortized cost to O(1) per byte appended).
    pub fn compact(&mut self) -> Result<()> {
        let t0 = crate::obs::enabled().then(Instant::now);
        let tmp_path = self.path.with_extension("compact");
        let mut tmp = File::create(&tmp_path)
            .with_context(|| format!("creating {}", tmp_path.display()))?;
        let mut ids: Vec<TenantId> = self.index.keys().copied().collect();
        ids.sort_unstable();
        let mut new_index = HashMap::with_capacity(ids.len());
        let mut off = 0u64;
        for tenant in ids {
            let payload = self
                .get(tenant)?
                .expect("indexed tenant vanished during compaction");
            let mut rec = Vec::with_capacity(RECORD_HEADER as usize + payload.len());
            rec.extend_from_slice(&(payload.len() as u32).to_le_bytes());
            rec.extend_from_slice(&crc32(&payload).to_le_bytes());
            rec.extend_from_slice(&payload);
            tmp.write_all(&rec)?;
            new_index.insert(
                tenant,
                Span {
                    off,
                    len: payload.len() as u32,
                },
            );
            off += rec.len() as u64;
        }
        tmp.sync_all()?;
        drop(tmp);
        std::fs::rename(&tmp_path, &self.path)
            .with_context(|| format!("renaming compacted log over {}", self.path.display()))?;
        // Make the rename itself durable.
        sync_dir(&self.path)?;
        self.file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(&self.path)?;
        self.index = new_index;
        self.file_bytes = off;
        self.live_bytes = off;
        self.stats.compactions += 1;
        if let Some(t0) = t0 {
            crate::obs::store().record_compaction(t0.elapsed());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::gsad::tests::{entries_equal, random_entry};
    use crate::util::prop;
    use crate::util::tmp::unique_temp_dir;

    fn tight_opts() -> LogOpts {
        LogOpts {
            garbage_threshold: 0.5,
            min_compact_bytes: 0,
        }
    }

    #[test]
    fn append_get_delete_and_reopen() {
        let dir = unique_temp_dir("log_basic");
        let path = dir.join("adapters.log");
        let mut rng = crate::util::rng::Rng::new(31);
        let e0 = random_entry(&mut rng, 0);
        let e1 = random_entry(&mut rng, 1);
        {
            let mut log = SegmentLog::open(&path, LogOpts::default()).unwrap();
            log.append(10, &gsad::encode_adapter(10, &e0)).unwrap();
            log.append(11, &gsad::encode_adapter(11, &e1)).unwrap();
            assert!(log.delete(10).unwrap());
            assert!(!log.delete(10).unwrap(), "double delete is a no-op");
            assert_eq!(log.tenant_ids(), vec![11]);
            assert!(log.get(10).unwrap().is_none());
        }
        // Reopen: replay reproduces the same live view.
        let mut log = SegmentLog::open(&path, LogOpts::default()).unwrap();
        assert_eq!(log.tenant_ids(), vec![11]);
        let payload = log.get(11).unwrap().expect("tenant 11 survives reopen");
        match gsad::decode(&payload).unwrap() {
            gsad::Record::Adapter { tenant, entry } => {
                assert_eq!(tenant, 11);
                assert!(entries_equal(&entry, &e1));
            }
            _ => panic!("wrong record"),
        }
        assert_eq!(log.stats().truncated_tail_bytes, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn updates_supersede_and_compaction_reclaims_garbage() {
        let dir = unique_temp_dir("log_compact");
        let path = dir.join("adapters.log");
        let mut rng = crate::util::rng::Rng::new(32);
        let mut log = SegmentLog::open(&path, tight_opts()).unwrap();
        // Repeated overwrites of one tenant: garbage ratio keeps crossing
        // 0.5, so compaction fires and the file stays bounded near one
        // live record.
        let entry = random_entry(&mut rng, 0);
        let payload = gsad::encode_adapter(1, &entry);
        for _ in 0..16 {
            log.append(1, &payload).unwrap();
        }
        assert!(log.stats().compactions > 0, "compaction never fired");
        assert!(
            log.file_bytes() <= 2 * (payload.len() as u64 + RECORD_HEADER),
            "file grew unboundedly: {} bytes for one live record of {}",
            log.file_bytes(),
            payload.len()
        );
        assert!(log.garbage_ratio() <= 0.5 + 1e-9);
        // The live record still reads back bit-identically after all that.
        let got = log.get(1).unwrap().unwrap();
        assert_eq!(got, payload);
        // And a reopen of the compacted file agrees.
        drop(log);
        let mut log = SegmentLog::open(&path, tight_opts()).unwrap();
        assert_eq!(log.get(1).unwrap().unwrap(), payload);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn auto_compact_gate_defers_compaction_to_an_external_caller() {
        // With the gate off, overwrites only *flag* compaction
        // (wants_compaction) — the request path never pays it; an explicit
        // compact() then reclaims the garbage, which is exactly the
        // maintenance thread's contract.
        let dir = unique_temp_dir("log_gate");
        let path = dir.join("adapters.log");
        let mut rng = crate::util::rng::Rng::new(35);
        let mut log = SegmentLog::open(&path, tight_opts()).unwrap();
        log.set_auto_compact(false);
        let payload = gsad::encode_adapter(1, &random_entry(&mut rng, 0));
        for _ in 0..8 {
            log.append(1, &payload).unwrap();
        }
        assert_eq!(log.stats().compactions, 0, "gated appends must not compact");
        assert!(log.wants_compaction(), "garbage past threshold must be flagged");
        log.compact().unwrap();
        assert_eq!(log.stats().compactions, 1);
        assert!(!log.wants_compaction());
        assert_eq!(log.get(1).unwrap().unwrap(), payload);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Ops for the crash-recovery property: register/overwrite/delete over
    /// a small tenant set, then cut the file at an arbitrary byte.
    #[derive(Debug, Clone)]
    struct CrashCase {
        ops: Vec<(TenantId, bool)>, // (tenant, is_delete)
        /// Cut position as a fraction (scaled 0..=1000) of the file length.
        cut_millis: usize,
    }

    fn shrink_crash(c: &CrashCase) -> Vec<CrashCase> {
        let mut out = Vec::new();
        if !c.ops.is_empty() {
            out.push(CrashCase {
                ops: c.ops[..c.ops.len() / 2].to_vec(),
                cut_millis: c.cut_millis,
            });
            let mut tail = c.ops.clone();
            tail.remove(0);
            out.push(CrashCase {
                ops: tail,
                cut_millis: c.cut_millis,
            });
        }
        for cut in prop::shrink_usize(c.cut_millis, 0) {
            out.push(CrashCase {
                ops: c.ops.clone(),
                cut_millis: cut,
            });
        }
        out
    }

    #[test]
    fn replay_after_torn_tail_recovers_exactly_the_prefix() {
        // Property (shrinking): write a log, truncate it at an arbitrary
        // byte (a simulated mid-write crash), reopen — the recovered live
        // view must equal replaying exactly the ops whose records fit
        // wholly below the cut, and the reopened log must keep working.
        prop::check_shrunk(
            "segment log torn-tail recovery",
            902,
            24,
            |rng| CrashCase {
                ops: (0..prop::size_in(rng, 1, 12))
                    .map(|_| (rng.below(4) as TenantId, rng.below(4) == 0))
                    .collect(),
                cut_millis: rng.below(1001),
            },
            shrink_crash,
            |case| {
                let dir = unique_temp_dir("log_crash");
                let path = dir.join("adapters.log");
                let mut rng = crate::util::rng::Rng::new(77);
                // Opts that never compact: compaction would legitimately
                // rewrite history and the byte-cut model assumes appends.
                let opts = LogOpts {
                    garbage_threshold: 1.1,
                    min_compact_bytes: u64::MAX,
                };
                let mut log = SegmentLog::open(&path, opts).unwrap();
                // (end_offset, simulated op) per applied op.
                let mut timeline: Vec<(u64, (TenantId, bool, Vec<u8>))> = Vec::new();
                for &(tenant, is_delete) in &case.ops {
                    if is_delete {
                        if log.delete(tenant).unwrap() {
                            timeline.push((log.file_bytes(), (tenant, true, Vec::new())));
                        }
                    } else {
                        let entry = random_entry(&mut rng, tenant as usize);
                        let payload = gsad::encode_adapter(tenant, &entry);
                        log.append(tenant, &payload).unwrap();
                        timeline.push((log.file_bytes(), (tenant, false, payload)));
                    }
                }
                let full = log.file_bytes();
                drop(log);
                let cut = (full as usize * case.cut_millis / 1000) as u64;
                let bytes = std::fs::read(&path).unwrap();
                std::fs::write(&path, &bytes[..cut as usize]).unwrap();

                // Expected: replay of the ops wholly below the cut.
                let mut expect: HashMap<TenantId, Vec<u8>> = HashMap::new();
                for (end, (tenant, is_delete, payload)) in &timeline {
                    if *end > cut {
                        break;
                    }
                    if *is_delete {
                        expect.remove(tenant);
                    } else {
                        expect.insert(*tenant, payload.clone());
                    }
                }

                let mut log = SegmentLog::open(&path, opts).unwrap();
                let mut want_ids: Vec<TenantId> = expect.keys().copied().collect();
                want_ids.sort_unstable();
                assert_eq!(log.tenant_ids(), want_ids, "live set after recovery");
                for (tenant, payload) in &expect {
                    assert_eq!(
                        log.get(*tenant).unwrap().as_deref(),
                        Some(payload.as_slice()),
                        "tenant {tenant} payload after recovery"
                    );
                }
                // The recovered log must accept appends again.
                let entry = random_entry(&mut rng, 0);
                log.append(99, &gsad::encode_adapter(99, &entry)).unwrap();
                assert!(log.contains(99));
                drop(log);
                // ...and a second reopen sees the post-recovery append too.
                let log = SegmentLog::open(&path, opts).unwrap();
                assert!(log.contains(99));
                assert_eq!(log.stats().truncated_tail_bytes, 0, "clean reopen");
                let _ = std::fs::remove_dir_all(&dir);
            },
        );
    }

    #[test]
    fn obs_records_append_fsync_and_compaction_when_enabled() {
        let _g = crate::obs::test_enable_lock();
        let dir = unique_temp_dir("log_obs");
        let path = dir.join("adapters.log");
        let mut rng = crate::util::rng::Rng::new(34);
        let mut log = SegmentLog::open(&path, tight_opts()).unwrap();
        let payload = gsad::encode_adapter(1, &random_entry(&mut rng, 0));

        let was = crate::obs::enabled();
        crate::obs::set_enabled(false);
        let before = log.stats();
        log.append(1, &payload).unwrap();
        // Disabled: the write path must not touch the global registry at
        // all, so its snapshot is taken *after* this append...
        let t0 = crate::obs::global().snapshot();
        crate::obs::set_enabled(true);
        for _ in 0..4 {
            log.append(1, &payload).unwrap(); // overwrites → compaction fires
        }
        crate::obs::set_enabled(was);
        let t1 = crate::obs::global().snapshot();
        // ...and the enabled appends show up as deltas (the registry is
        // shared process-wide: assert ≥, never exact counts).
        let count = |s: &crate::obs::RegistrySnapshot, n: &str| {
            s.histograms.get(n).map(|h| h.count()).unwrap_or(0)
        };
        assert!(count(&t1, "store_append_ns") - count(&t0, "store_append_ns") >= 4);
        assert!(count(&t1, "store_fsync_ns") - count(&t0, "store_fsync_ns") >= 4);
        assert!(
            log.stats().compactions > before.compactions,
            "overwrites under tight opts must compact"
        );
        assert!(
            count(&t1, "store_compaction_ns") - count(&t0, "store_compaction_ns") >= 1
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mid_file_bitflip_recovers_the_prefix_cleanly() {
        let dir = unique_temp_dir("log_flip");
        let path = dir.join("adapters.log");
        let mut rng = crate::util::rng::Rng::new(33);
        let mut log = SegmentLog::open(&path, LogOpts::default()).unwrap();
        let mut first_end = 0;
        for t in 0..3u64 {
            let e = random_entry(&mut rng, t as usize);
            log.append(t, &gsad::encode_adapter(t, &e)).unwrap();
            if t == 0 {
                first_end = log.file_bytes();
            }
        }
        drop(log);
        let mut bytes = std::fs::read(&path).unwrap();
        let idx = first_end as usize + RECORD_HEADER as usize + 20; // inside record 2's payload
        bytes[idx] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let log = SegmentLog::open(&path, LogOpts::default()).unwrap();
        assert_eq!(log.tenant_ids(), vec![0], "only the intact prefix survives");
        assert!(log.stats().truncated_tail_bytes > 0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
