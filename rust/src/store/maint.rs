//! Background maintenance thread: compaction and spill-tier writes off
//! the request path (DESIGN.md §13).
//!
//! Before this module, whichever request tripped a garbage threshold
//! paid a synchronous compaction, and every RAM-cache eviction paid the
//! merged-weight encode + `fs::write` inline. The [`Maintainer`] owns
//! both: requests only *enqueue* work (an O(1) push under a short
//! mutex), and the bulk encode/fs ops happen on this thread.
//!
//! Safety under live re-registration follows the split-phase
//! generation-fenced [`SpillTier`] design from PR 5:
//!
//! - spill writes run `reserve` → [`super::spill::PendingSpill::write`] →
//!   `commit` with the bulk I/O outside the tier lock, and the tier's
//!   generation tags mean a reader that observed a stale entry can never
//!   invalidate a racing re-put's fresh file;
//! - the maintainer is the *single* spill writer, and its queue is FIFO,
//!   so two queued writes for the same tenant land oldest-first — the
//!   newest merged weights always win the index, and a stale file is
//!   caught by the params-CRC tag on read regardless;
//! - compaction takes exactly one shard's lock at a time
//!   ([`ShardedLog::compact_shard`]); while the maintainer is alive it
//!   flips the shards' inline auto-compaction off, so the request path
//!   provably never compacts — and flips it back on at shutdown so an
//!   unmaintained store still stays bounded.
//!
//! Every cycle (a queued job, an explicit [`Maintainer::kick`], or the
//! `interval` tick) drains the spill queue, then scans the shards for
//! garbage past policy. [`MaintStats`] accounts the whole plane:
//! compaction/spill-write counts and the total off-request-path busy
//! time, mirrored into the global `store_maint_*` metrics when obs is on.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::serve::registry::TenantId;

use super::gsad;
use super::shard::ShardedLog;
use super::spill::SpillTier;

/// Default `--maint-interval-ms`: how often the maintainer wakes with no
/// queued work to scan for compactions.
pub const DEFAULT_MAINT_INTERVAL_MS: u64 = 200;

/// Monotonic counters for the maintenance plane (snapshot with
/// [`Maintainer::stats`]; the `maint` section of `BENCH_store.json`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MaintStats {
    /// Maintenance cycles run (ticks, kicks and job wakeups).
    pub ticks: u64,
    /// Shard compactions performed by this thread.
    pub compactions: u64,
    /// Spill files written by this thread.
    pub spill_writes: u64,
    /// Spill writes that failed (reservation refused or I/O error).
    pub spill_write_failures: u64,
    /// High-water mark of the job queue.
    pub max_queue_depth: u64,
    /// Total busy time on this thread — work the request path no longer
    /// pays.
    pub off_path_ns: u64,
}

/// A queued spill write: everything needed to encode and write the
/// merged file off-path. The flat buffer is shared with the RAM cache's
/// (just-evicted) entry, so enqueueing copies nothing.
struct SpillJob {
    tenant: TenantId,
    params_crc: u32,
    flat: Arc<Vec<f32>>,
}

struct State {
    jobs: VecDeque<SpillJob>,
    kicks: u64,
    shutdown: bool,
    /// A cycle is in flight (jobs already drained from the queue).
    busy: bool,
}

struct Inner {
    state: Mutex<State>,
    /// Wakes the maintenance thread (new job / kick / shutdown).
    wake: Condvar,
    /// Wakes [`Maintainer::drain`] waiters (cycle finished).
    done: Condvar,
    stats: Mutex<MaintStats>,
    log: Option<Arc<ShardedLog>>,
    spill: Option<Arc<Mutex<SpillTier>>>,
    interval: Duration,
}

/// Handle to the background maintenance thread. Dropping it shuts the
/// thread down (draining queued spill writes first).
pub struct Maintainer {
    inner: Arc<Inner>,
    thread: Mutex<Option<JoinHandle<()>>>,
}

impl Maintainer {
    /// Spawn the maintenance thread over an optional factor tier and an
    /// optional spill tier. Takes ownership of compaction for `log`
    /// (inline auto-compaction is disabled until shutdown).
    pub fn spawn(
        interval: Duration,
        log: Option<Arc<ShardedLog>>,
        spill: Option<Arc<Mutex<SpillTier>>>,
    ) -> Maintainer {
        if let Some(log) = &log {
            log.set_auto_compact(false);
        }
        let inner = Arc::new(Inner {
            state: Mutex::new(State {
                jobs: VecDeque::new(),
                kicks: 0,
                shutdown: false,
                busy: false,
            }),
            wake: Condvar::new(),
            done: Condvar::new(),
            stats: Mutex::new(MaintStats::default()),
            log,
            spill,
            interval: interval.max(Duration::from_millis(1)),
        });
        let worker = Arc::clone(&inner);
        let thread = std::thread::Builder::new()
            .name("gsoft-maint".into())
            .spawn(move || run(&worker))
            .expect("failed to spawn maintenance thread");
        Maintainer {
            inner,
            thread: Mutex::new(Some(thread)),
        }
    }

    /// Enqueue a spill write (the request path's entire cost: one push
    /// under a short mutex). Jobs enqueued after shutdown are dropped.
    pub fn enqueue_spill(&self, tenant: TenantId, params_crc: u32, flat: Arc<Vec<f32>>) {
        let depth = {
            let mut st = self.inner.state.lock().unwrap();
            if st.shutdown {
                return;
            }
            st.jobs.push_back(SpillJob {
                tenant,
                params_crc,
                flat,
            });
            st.jobs.len()
        };
        {
            let mut stats = self.inner.stats.lock().unwrap();
            stats.max_queue_depth = stats.max_queue_depth.max(depth as u64);
        }
        if crate::obs::enabled() {
            crate::obs::store().set_maint_queue_depth(depth);
        }
        self.inner.wake.notify_one();
    }

    /// Force a maintenance cycle now (tests and benches; production
    /// callers just let the interval tick).
    pub fn kick(&self) {
        self.inner.state.lock().unwrap().kicks += 1;
        self.inner.wake.notify_one();
    }

    /// Block until every job enqueued before this call has been
    /// processed and the current cycle (if any) has finished.
    pub fn drain(&self) {
        let mut st = self.inner.state.lock().unwrap();
        st.kicks += 1;
        self.inner.wake.notify_one();
        while !(st.jobs.is_empty() && !st.busy && st.kicks == 0) {
            st = self.inner.done.wait(st).unwrap();
        }
    }

    pub fn stats(&self) -> MaintStats {
        *self.inner.stats.lock().unwrap()
    }

    pub fn queue_depth(&self) -> usize {
        self.inner.state.lock().unwrap().jobs.len()
    }

    /// Stop the thread: queued spill writes drain first, then compaction
    /// ownership is handed back to the inline path. Idempotent.
    pub fn shutdown(&self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.shutdown = true;
        }
        self.inner.wake.notify_one();
        if let Some(thread) = self.thread.lock().unwrap().take() {
            let _ = thread.join();
            if let Some(log) = &self.inner.log {
                log.set_auto_compact(true);
            }
        }
    }
}

impl Drop for Maintainer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn run(inner: &Inner) {
    loop {
        // Wait for work, a kick, shutdown, or the compaction-scan tick.
        let (jobs, shutdown) = {
            let mut st = inner.state.lock().unwrap();
            loop {
                if st.shutdown || !st.jobs.is_empty() || st.kicks > 0 {
                    break;
                }
                let (guard, timeout) = inner.wake.wait_timeout(st, inner.interval).unwrap();
                st = guard;
                if timeout.timed_out() {
                    break; // interval tick: run a compaction scan
                }
            }
            st.busy = true;
            let jobs: Vec<SpillJob> = st.jobs.drain(..).collect();
            (jobs, st.shutdown)
        };

        let t0 = Instant::now();
        let obs = crate::obs::enabled();
        let mut cycle = MaintStats {
            ticks: 1,
            ..MaintStats::default()
        };
        if obs && !jobs.is_empty() {
            crate::obs::store().set_maint_queue_depth(0);
        }
        if let Some(spill) = &inner.spill {
            for job in jobs {
                // Bulk encode outside the tier lock; reserve/commit are
                // the metadata-only lock-held phases (generation-fenced —
                // see the module docs).
                let bytes = gsad::encode_merged(job.tenant, job.params_crc, &job.flat);
                let pending = spill.lock().unwrap().reserve(job.tenant, bytes.len() as u64);
                let Some(pending) = pending else {
                    cycle.spill_write_failures += 1;
                    continue;
                };
                match pending.write(&bytes) {
                    Ok(()) => {
                        spill.lock().unwrap().commit(pending);
                        cycle.spill_writes += 1;
                        if obs {
                            crate::obs::store().record_maint_spill_write();
                        }
                    }
                    Err(_) => {
                        spill.lock().unwrap().abort(pending);
                        cycle.spill_write_failures += 1;
                    }
                }
            }
        }
        if let Some(log) = &inner.log {
            for i in log.shards_wanting_compaction() {
                if log.compact_shard(i).is_ok() {
                    cycle.compactions += 1;
                    if obs {
                        crate::obs::store().record_maint_compaction();
                    }
                }
            }
        }
        cycle.off_path_ns = t0.elapsed().as_nanos() as u64;
        if obs {
            let store = crate::obs::store();
            store.record_maint_tick();
            store.record_maint_cycle(t0.elapsed());
        }
        {
            let mut stats = inner.stats.lock().unwrap();
            stats.ticks += cycle.ticks;
            stats.compactions += cycle.compactions;
            stats.spill_writes += cycle.spill_writes;
            stats.spill_write_failures += cycle.spill_write_failures;
            stats.off_path_ns += cycle.off_path_ns;
        }

        let mut st = inner.state.lock().unwrap();
        st.busy = false;
        st.kicks = 0;
        inner.done.notify_all();
        if shutdown && st.jobs.is_empty() {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::gsad::tests::random_entry;
    use crate::store::log::LogOpts;
    use crate::util::tmp::unique_temp_dir;

    #[test]
    fn enqueued_spill_writes_land_off_path() {
        let dir = unique_temp_dir("maint_spill");
        let spill = Arc::new(Mutex::new(SpillTier::open(&dir, 1 << 20).unwrap()));
        let maint = Maintainer::spawn(Duration::from_secs(3600), None, Arc::clone(&spill).into());
        let flat = Arc::new(vec![1.5f32; 64]);
        maint.enqueue_spill(3, 0x33, Arc::clone(&flat));
        maint.drain();
        assert_eq!(
            spill.lock().unwrap().get(3, 0x33).as_deref(),
            Some(flat.as_slice())
        );
        let s = maint.stats();
        assert_eq!(s.spill_writes, 1);
        assert_eq!(s.spill_write_failures, 0);
        assert!(s.off_path_ns > 0);
        maint.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fifo_queue_means_the_newest_re_put_wins() {
        // A re-registered tenant can have two spill writes queued: the
        // stale merge first, the fresh one second. FIFO processing plus
        // the tier's rename-replace means the fresh file is what remains.
        let dir = unique_temp_dir("maint_fifo");
        let spill = Arc::new(Mutex::new(SpillTier::open(&dir, 1 << 20).unwrap()));
        let maint = Maintainer::spawn(Duration::from_secs(3600), None, Arc::clone(&spill).into());
        let stale = Arc::new(vec![1.0f32; 16]);
        let fresh = Arc::new(vec![2.0f32; 16]);
        maint.enqueue_spill(7, 0xAA, stale);
        maint.enqueue_spill(7, 0xBB, Arc::clone(&fresh));
        maint.drain();
        assert_eq!(
            spill.lock().unwrap().get(7, 0xBB).as_deref(),
            Some(fresh.as_slice()),
            "the newest enqueued write must win the index"
        );
        maint.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn maintainer_owns_compaction_and_hands_it_back() {
        let dir = unique_temp_dir("maint_compact");
        let log = Arc::new(
            ShardedLog::open(
                &dir,
                2,
                LogOpts {
                    garbage_threshold: 0.5,
                    min_compact_bytes: 0,
                },
            )
            .unwrap(),
        );
        let maint = Maintainer::spawn(Duration::from_secs(3600), Some(Arc::clone(&log)), None);
        let mut rng = crate::util::rng::Rng::new(61);
        let payload = crate::store::gsad::encode_adapter(1, &random_entry(&mut rng, 0));
        for _ in 0..8 {
            log.append(1, &payload).unwrap();
        }
        assert_eq!(
            log.stats().compactions,
            0,
            "request-path appends must not compact while the maintainer is alive"
        );
        maint.drain();
        let s = maint.stats();
        assert!(s.compactions >= 1, "the maintainer compacts the dirty shard");
        assert_eq!(log.stats().compactions, s.compactions);
        assert_eq!(log.get(1).unwrap().unwrap(), payload);
        maint.shutdown();
        // Ownership handed back: inline appends compact again.
        for _ in 0..8 {
            log.append(1, &payload).unwrap();
        }
        assert!(log.stats().compactions > s.compactions);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn shutdown_drains_queued_jobs() {
        let dir = unique_temp_dir("maint_shutdown");
        let spill = Arc::new(Mutex::new(SpillTier::open(&dir, 1 << 20).unwrap()));
        let maint = Maintainer::spawn(Duration::from_secs(3600), None, Arc::clone(&spill).into());
        for t in 0..8u64 {
            maint.enqueue_spill(t, t as u32, Arc::new(vec![t as f32; 8]));
        }
        maint.shutdown();
        let mut tier = spill.lock().unwrap();
        for t in 0..8u64 {
            assert!(
                tier.get(t, t as u32).is_some(),
                "job for tenant {t} must land before shutdown completes"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
