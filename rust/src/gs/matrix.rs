//! The two-factor GS class `GS(P_L, P, P_R)` of Definition 3.1:
//! `A = P_L (L P R) P_R` with `L = diag(L_1..L_{k_L})`,
//! `R = diag(R_1..R_{k_R})`.
//!
//! [`GsSpec`] fixes the structural data (permutations and block shapes —
//! "in practice we fix P_L, P, P_R depending on the application and only
//! make matrices L, R subject for change"); [`GsMatrix`] carries the
//! trainable factors.

use crate::linalg::Mat;
use crate::util::rng::Rng;

use super::blockdiag::BlockDiag;
use super::perm::{perm_kn, Perm};

/// Structural description of a `GS(P_L, P, P_R)` class.
#[derive(Clone, Debug)]
pub struct GsSpec {
    pub p_l: Perm,
    pub p: Perm,
    pub p_r: Perm,
    pub k_l: usize,
    pub k_r: usize,
    /// L block shape `(b_L^1, b_L^2)`.
    pub b_l: (usize, usize),
    /// R block shape `(b_R^1, b_R^2)`.
    pub b_r: (usize, usize),
}

impl GsSpec {
    /// Validated constructor enforcing the Definition 3.1 size constraints:
    /// `b_L^2·k_L = b_R^1·k_R = s`, `P` is `s×s`, `P_L` is `m×m`, `P_R` is
    /// `n×n`.
    pub fn new(
        p_l: Perm,
        p: Perm,
        p_r: Perm,
        k_l: usize,
        k_r: usize,
        b_l: (usize, usize),
        b_r: (usize, usize),
    ) -> GsSpec {
        let s = b_l.1 * k_l;
        assert_eq!(
            s,
            b_r.0 * k_r,
            "inner sizes must agree: b_L^2*k_L = {} vs b_R^1*k_R = {}",
            s,
            b_r.0 * k_r
        );
        assert_eq!(p.n(), s, "P must be s×s");
        assert_eq!(p_l.n(), b_l.0 * k_l, "P_L must be m×m");
        assert_eq!(p_r.n(), b_r.1 * k_r, "P_R must be n×n");
        GsSpec {
            p_l,
            p,
            p_r,
            k_l,
            k_r,
            b_l,
            b_r,
        }
    }

    /// The GSOFT spec of §6.1: square `d×d`, `r` blocks of size `b×b` in
    /// both factors, `Q = P^T L P R` with `P = P_(r, d)` (the paper uses
    /// `P_(r,br)`), `P_R = I`.
    pub fn gsoft(d: usize, b: usize) -> GsSpec {
        assert!(d % b == 0, "block size must divide dimension");
        let r = d / b;
        let p = perm_kn(r, d);
        GsSpec::new(
            p.inverse(), // P_L = P^T
            p,
            Perm::identity(d),
            r,
            r,
            (b, b),
            (b, b),
        )
    }

    /// The convolutional variant (§3): `P_L = I`, `P_R = P`.
    pub fn conv(d: usize, b: usize) -> GsSpec {
        assert!(d % b == 0);
        let r = d / b;
        let p = perm_kn(r, d);
        GsSpec::new(
            Perm::identity(d),
            p.clone(),
            p,
            r,
            r,
            (b, b),
            (b, b),
        )
    }

    /// Output dimension `m`.
    pub fn m(&self) -> usize {
        self.b_l.0 * self.k_l
    }

    /// Input dimension `n`.
    pub fn n(&self) -> usize {
        self.b_r.1 * self.k_r
    }

    /// Inner dimension `s`.
    pub fn s(&self) -> usize {
        self.b_l.1 * self.k_l
    }

    /// Trainable parameters of a member of this class.
    pub fn param_count(&self) -> usize {
        self.k_l * self.b_l.0 * self.b_l.1 + self.k_r * self.b_r.0 * self.b_r.1
    }

    /// Sample a member with Gaussian blocks.
    pub fn random_member(&self, std: f64, rng: &mut Rng) -> GsMatrix {
        GsMatrix {
            spec: self.clone(),
            l: BlockDiag::randn(self.k_l, self.b_l.0, self.b_l.1, std, rng),
            r: BlockDiag::randn(self.k_r, self.b_r.0, self.b_r.1, std, rng),
        }
    }

    /// Sample a member with *orthogonal* blocks (requires square blocks).
    pub fn random_orthogonal_member(&self, rng: &mut Rng) -> GsMatrix {
        assert_eq!(self.b_l.0, self.b_l.1, "orthogonal blocks must be square");
        assert_eq!(self.b_r.0, self.b_r.1, "orthogonal blocks must be square");
        GsMatrix {
            spec: self.clone(),
            l: BlockDiag::rand_orthogonal(self.k_l, self.b_l.0, rng),
            r: BlockDiag::rand_orthogonal(self.k_r, self.b_r.0, rng),
        }
    }

    /// The identity member (identity blocks; requires square blocks and
    /// `P_L (P) P_R = I`-compatible perms only give exact identity for the
    /// GSOFT spec, where `P^T I P I = I`).
    pub fn identity_member(&self) -> GsMatrix {
        GsMatrix {
            spec: self.clone(),
            l: BlockDiag::identity(self.k_l, self.b_l.0),
            r: BlockDiag::identity(self.k_r, self.b_r.0),
        }
    }
}

/// A concrete member of a `GS(P_L, P, P_R)` class.
#[derive(Clone, Debug)]
pub struct GsMatrix {
    pub spec: GsSpec,
    pub l: BlockDiag,
    pub r: BlockDiag,
}

impl GsMatrix {
    pub fn new(spec: GsSpec, l: BlockDiag, r: BlockDiag) -> GsMatrix {
        assert_eq!(l.k(), spec.k_l);
        assert_eq!(r.k(), spec.k_r);
        for blk in &l.blocks {
            assert_eq!((blk.rows, blk.cols), spec.b_l);
        }
        for blk in &r.blocks {
            assert_eq!((blk.rows, blk.cols), spec.b_r);
        }
        GsMatrix { spec, l, r }
    }

    /// Dense materialization `P_L (L P R) P_R`.
    pub fn to_dense(&self) -> Mat {
        let r = self.r.to_mat();
        let pr = self.spec.p.apply_rows(&r);
        let lpr = self.l.matmul_right(&pr);
        let pl_lpr = self.spec.p_l.apply_rows(&lpr);
        // (X) P_R : columns permuted.
        self.spec.p_r.apply_cols(&pl_lpr)
    }

    /// Structured apply `A · X` for `X: n×t` — never materializes the dense
    /// `m×n` matrix. This is the hot path the paper's efficiency claims are
    /// about: two fused kernel passes ([`crate::kernel::gs_apply`]), each a
    /// grouped (block-diagonal) GEMM with its relayouts folded in as
    /// gathers/scatters.
    pub fn apply(&self, x: &Mat) -> Mat {
        crate::kernel::gs_apply(self, x, crate::kernel::ctx())
    }

    /// Structured apply to a single vector.
    pub fn apply_vec(&self, x: &[f64]) -> Vec<f64> {
        let x1 = self.spec.p_r.apply_vec(x);
        let x2 = self.r.matvec(&x1);
        let x3 = self.spec.p.apply_vec(&x2);
        let x4 = self.l.matvec(&x3);
        self.spec.p_l.apply_vec(&x4)
    }

    /// Trainable parameter count.
    pub fn param_count(&self) -> usize {
        self.l.param_count() + self.r.param_count()
    }

    /// Max per-block orthogonality error over both factors.
    pub fn blockwise_orthogonality_error(&self) -> f64 {
        self.l
            .blockwise_orthogonality_error()
            .max(self.r.blockwise_orthogonality_error())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn random_spec(rng: &mut Rng) -> GsSpec {
        // Draw compatible shapes: s = lcm-ish via common grid.
        let b_l2 = prop::size_in(rng, 1, 4);
        let k_l = prop::size_in(rng, 1, 4);
        let s = b_l2 * k_l;
        // choose k_r dividing s
        let divisors: Vec<usize> = (1..=s).filter(|d| s % d == 0).collect();
        let k_r = *rng.choice(&divisors);
        let b_r1 = s / k_r;
        let b_l1 = prop::size_in(rng, 1, 4);
        let b_r2 = prop::size_in(rng, 1, 4);
        let m = b_l1 * k_l;
        let n = b_r2 * k_r;
        GsSpec::new(
            Perm::random(m, rng),
            Perm::random(s, rng),
            Perm::random(n, rng),
            k_l,
            k_r,
            (b_l1, b_l2),
            (b_r1, b_r2),
        )
    }

    #[test]
    fn structured_apply_matches_dense() {
        prop::check("GS apply == dense apply", 91, |rng| {
            let spec = random_spec(rng);
            let a = spec.random_member(1.0, rng);
            let x = Mat::randn(spec.n(), prop::size_in(rng, 1, 4), 1.0, rng);
            let dense = a.to_dense().matmul(&x);
            let fast = a.apply(&x);
            assert!(dense.fro_dist(&fast) < 1e-9);

            let xv: Vec<f64> = (0..spec.n()).map(|_| rng.normal()).collect();
            let y1 = a.apply_vec(&xv);
            let y2 = a.to_dense().matvec(&xv);
            for (p, q) in y1.iter().zip(y2.iter()) {
                assert!((p - q).abs() < 1e-9);
            }
        });
    }

    #[test]
    fn gsoft_spec_orthogonal_member_is_orthogonal() {
        // §4: per-block orthogonality of L and R ⇒ the whole GS matrix is
        // orthogonal (permutations are orthogonal, products of orthogonal
        // matrices are orthogonal).
        prop::check("orthogonal blocks => orthogonal GS", 92, |rng| {
            let b = [2usize, 4, 8][rng.below(3)];
            let r = [2usize, 3, 4][rng.below(3)];
            let spec = GsSpec::gsoft(b * r, b);
            let q = spec.random_orthogonal_member(rng);
            let dense = q.to_dense();
            assert!(dense.is_orthogonal(1e-8), "err={}", dense.orthogonality_error());
        });
    }

    #[test]
    fn gsoft_identity_member_is_identity() {
        // §6.1: initializing each block with identity gives Q = I
        // (P^T I P I = I).
        for (d, b) in [(8, 2), (16, 4), (32, 8), (12, 3)] {
            let spec = GsSpec::gsoft(d, b);
            let q = spec.identity_member();
            assert!(
                q.to_dense().fro_dist(&Mat::eye(d)) < 1e-12,
                "d={d} b={b}"
            );
        }
    }

    #[test]
    fn gsoft_param_count_formula() {
        // §5.2 example: d=1024, b=32 → 2·32³ parameters... per Q with r=32
        // blocks of 32² each in both factors: 2·r·b² = 2·1024·32 = 2·32³.
        let spec = GsSpec::gsoft(1024, 32);
        assert_eq!(spec.param_count(), 2 * 32 * 32 * 32);
        assert_eq!(spec.param_count(), spec.random_member(1.0, &mut Rng::new(0)).param_count());
    }

    #[test]
    fn gsoft_q_is_dense_with_m2() {
        // Theorem 2 for m=2: with b ≥ r... more precisely GSOFT's two
        // factors with P_(r,d) produce a fully dense matrix when b ≥ r
        // (log_b(r) ≤ 1). Use generic (non-zero) random blocks.
        let mut rng = Rng::new(7);
        for (d, b) in [(16, 4), (64, 8), (36, 6)] {
            let spec = GsSpec::gsoft(d, b); // r = d/b = b here
            let a = spec.random_member(1.0, &mut rng);
            assert_eq!(a.to_dense().nnz(1e-12), d * d, "d={d} b={b}");
        }
    }

    #[test]
    #[should_panic(expected = "inner sizes")]
    fn bad_spec_rejected() {
        GsSpec::new(
            Perm::identity(4),
            Perm::identity(4),
            Perm::identity(6),
            2,
            3,
            (2, 2),
            (1, 2),
        );
    }
}
