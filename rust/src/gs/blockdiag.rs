//! Block-diagonal matrices — the `L` and `R` factors of a GS matrix.
//!
//! Blocks may be rectangular (Definition 3.1 allows `b^1 × b^2` blocks);
//! the orthogonal parametrization (§4) uses square blocks produced by the
//! Cayley transform.

use crate::linalg::{cayley, Mat};
use crate::util::rng::Rng;

/// `diag(B_1, …, B_k)` with arbitrary rectangular blocks.
#[derive(Clone, Debug)]
pub struct BlockDiag {
    pub blocks: Vec<Mat>,
}

impl BlockDiag {
    pub fn new(blocks: Vec<Mat>) -> BlockDiag {
        assert!(!blocks.is_empty());
        BlockDiag { blocks }
    }

    /// `k` identical-shape zero blocks.
    pub fn zeros(k: usize, b_rows: usize, b_cols: usize) -> BlockDiag {
        BlockDiag {
            blocks: (0..k).map(|_| Mat::zeros(b_rows, b_cols)).collect(),
        }
    }

    /// Identity (square blocks).
    pub fn identity(k: usize, b: usize) -> BlockDiag {
        BlockDiag {
            blocks: (0..k).map(|_| Mat::eye(b)).collect(),
        }
    }

    /// Gaussian random blocks of a common shape.
    pub fn randn(k: usize, b_rows: usize, b_cols: usize, std: f64, rng: &mut Rng) -> BlockDiag {
        BlockDiag {
            blocks: (0..k).map(|_| Mat::randn(b_rows, b_cols, std, rng)).collect(),
        }
    }

    /// Random block-diag with *orthogonal* square blocks.
    pub fn rand_orthogonal(k: usize, b: usize, rng: &mut Rng) -> BlockDiag {
        BlockDiag {
            blocks: (0..k).map(|_| Mat::rand_orthogonal(b, rng)).collect(),
        }
    }

    /// Cayley-parametrized orthogonal block-diag: block `i` is
    /// `cayley(A_i - A_i^T)` — the paper's per-block orthogonality
    /// enforcement, identity at `A = 0`.
    pub fn cayley_from(params: &[Mat]) -> BlockDiag {
        BlockDiag {
            blocks: params.iter().map(cayley::cayley_unconstrained).collect(),
        }
    }

    pub fn k(&self) -> usize {
        self.blocks.len()
    }

    /// Total rows.
    pub fn rows(&self) -> usize {
        self.blocks.iter().map(|b| b.rows).sum()
    }

    /// Total cols.
    pub fn cols(&self) -> usize {
        self.blocks.iter().map(|b| b.cols).sum()
    }

    /// Trainable parameter count (entries of all blocks).
    pub fn param_count(&self) -> usize {
        self.blocks.iter().map(|b| b.rows * b.cols).sum()
    }

    /// Dense materialization.
    pub fn to_mat(&self) -> Mat {
        let mut out = Mat::zeros(self.rows(), self.cols());
        let (mut r0, mut c0) = (0, 0);
        for b in &self.blocks {
            out.set_block(r0, c0, b);
            r0 += b.rows;
            c0 += b.cols;
        }
        out
    }

    /// `self · a` without materializing the dense form — one fused-kernel
    /// pass ([`crate::kernel::fused_apply`] with no relayouts, parallel
    /// over blocks for large applies). This is the "group" half of
    /// group-and-shuffle.
    pub fn matmul_right(&self, a: &Mat) -> Mat {
        crate::kernel::fused_apply(self, None, None, a, crate::kernel::ctx())
    }

    /// Apply to a vector.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols(), x.len());
        let mut y = vec![0.0; self.rows()];
        let (mut r0, mut c0) = (0, 0);
        for blk in &self.blocks {
            for i in 0..blk.rows {
                let mut acc = 0.0;
                for kk in 0..blk.cols {
                    acc += blk[(i, kk)] * x[c0 + kk];
                }
                y[r0 + i] = acc;
            }
            r0 += blk.rows;
            c0 += blk.cols;
        }
        y
    }

    /// Max per-block orthogonality error (`||B_i^T B_i - I||_F`).
    pub fn blockwise_orthogonality_error(&self) -> f64 {
        self.blocks
            .iter()
            .map(|b| b.orthogonality_error())
            .fold(0.0, f64::max)
    }

    /// Transpose (block-wise).
    pub fn t(&self) -> BlockDiag {
        BlockDiag {
            blocks: self.blocks.iter().map(|b| b.t()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn dense_and_structured_apply_agree() {
        prop::check("blockdiag apply == dense apply", 81, |rng| {
            let k = prop::size_in(rng, 1, 5);
            let br = prop::size_in(rng, 1, 5);
            let bc = prop::size_in(rng, 1, 5);
            let bd = BlockDiag::randn(k, br, bc, 1.0, rng);
            let a = Mat::randn(bd.cols(), prop::size_in(rng, 1, 4), 1.0, rng);
            let dense = bd.to_mat().matmul(&a);
            let fast = bd.matmul_right(&a);
            assert!(dense.fro_dist(&fast) < 1e-10);

            let x: Vec<f64> = (0..bd.cols()).map(|_| rng.normal()).collect();
            let y1 = bd.matvec(&x);
            let y2 = bd.to_mat().matvec(&x);
            for (a, b) in y1.iter().zip(y2.iter()) {
                assert!((a - b).abs() < 1e-10);
            }
        });
    }

    #[test]
    fn cayley_blocks_give_orthogonal_blockdiag() {
        prop::check("cayley blockdiag orthogonal", 82, |rng| {
            let (b, k) = prop::block_shape(rng, 32);
            let params: Vec<Mat> = (0..k).map(|_| Mat::randn(b, b, 1.0, rng)).collect();
            let bd = BlockDiag::cayley_from(&params);
            assert!(bd.blockwise_orthogonality_error() < 1e-8);
            // The whole block-diagonal matrix is then orthogonal (§4).
            assert!(bd.to_mat().is_orthogonal(1e-8));
        });
    }

    #[test]
    fn identity_blockdiag() {
        let bd = BlockDiag::identity(3, 4);
        assert!(bd.to_mat().fro_dist(&Mat::eye(12)) < 1e-15);
        assert_eq!(bd.param_count(), 3 * 16);
    }

    #[test]
    fn transpose_matches_dense_transpose() {
        let mut rng = Rng::new(4);
        let bd = BlockDiag::randn(3, 2, 5, 1.0, &mut rng);
        assert!(bd.t().to_mat().fro_dist(&bd.to_mat().t()) < 1e-15);
    }

    #[test]
    fn rectangular_sizes() {
        let bd = BlockDiag::zeros(4, 3, 7);
        assert_eq!(bd.rows(), 12);
        assert_eq!(bd.cols(), 28);
    }
}
