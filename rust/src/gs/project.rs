//! Algorithm 1: projection `π(A)` onto `GS(P_L, P, P_R)` in Frobenius
//! norm, plus the constructive side of Theorem 1 (skeleton factorization
//! with orthonormal left factors).
//!
//! Thanks to Proposition 1 the projection decouples over the `k_L × k_R`
//! blocks of `P_L^T A P_R^T`: each block is SVD-truncated to the rank
//! `r_{k1,k2}` its permutation routing allows, and the factors
//! `U_r Σ_r^{1/2}` / `Σ_r^{1/2} V_r^T` are packed into the columns of
//! `L_{k1}` / rows of `R_{k2}` that `P` links.

use crate::linalg::{qr, svd, Mat};

use super::blockdiag::BlockDiag;
use super::lowrank::block_terms;
use super::matrix::{GsMatrix, GsSpec};

/// Project `a` onto the class described by `spec` (Algorithm 1).
pub fn project(a: &Mat, spec: &GsSpec) -> GsMatrix {
    project_impl(a, spec, false)
}

/// Theorem-1 variant: same routing, but the per-block skeleton is taken
/// with *orthonormal* `U` factors (`U^T U = I`, scale carried by `V`).
/// For an orthogonal `A ∈ GS(P_L,P,P_R)` this recovers a representation
/// whose `L` and `R` blocks are orthogonal — the content of Theorem 1.
pub fn skeleton_orthonormal(a: &Mat, spec: &GsSpec) -> GsMatrix {
    project_impl(a, spec, true)
}

fn project_impl(a: &Mat, spec: &GsSpec, orthonormal_u: bool) -> GsMatrix {
    assert_eq!(a.rows, spec.m(), "input rows must match spec");
    assert_eq!(a.cols, spec.n(), "input cols must match spec");
    // B = P_L^T A P_R^T: undo the outer permutations.
    // P_L^T · A permutes rows by σ_L^{-1}; A · P_R^T permutes columns.
    let b = spec
        .p_r
        .inverse()
        .apply_cols(&spec.p_l.inverse().apply_rows(a));

    let (b_l1, b_l2) = spec.b_l;
    let (b_r1, b_r2) = spec.b_r;
    let mut l = BlockDiag::zeros(spec.k_l, b_l1, b_l2);
    let mut r = BlockDiag::zeros(spec.k_r, b_r1, b_r2);
    let terms = block_terms(spec);

    for k1 in 0..spec.k_l {
        for k2 in 0..spec.k_r {
            let idxs = &terms[k1][k2];
            if idxs.is_empty() {
                continue;
            }
            let rank = idxs.len().min(b_l1).min(b_r2);
            let blk = b.block(k1 * b_l1, k2 * b_r2, b_l1, b_r2);
            let (uf, vf) = if orthonormal_u {
                // Skeleton U V^T with U^T U = I: U = svd.u (orthonormal),
                // V = svd.v · diag(s).
                let d = svd::svd(&blk);
                let mut uf = Mat::zeros(b_l1, rank);
                let mut vf = Mat::zeros(b_r2, rank);
                for t in 0..rank {
                    for i in 0..b_l1 {
                        uf[(i, t)] = d.u[(i, t)];
                    }
                    for i in 0..b_r2 {
                        vf[(i, t)] = d.v[(i, t)] * d.s[t];
                    }
                }
                (uf, vf)
            } else {
                svd::truncated_factors(&blk, rank)
            };
            // Pack the t-th factor pair into column σ(i_t) of L (local to
            // block k1) and row i_t of R (local to block k2). When the
            // routing provides more links than the numerical rank needs
            // (idxs.len() > rank), the extra columns/rows stay zero... but
            // for the orthonormal variant we must still fill U columns to
            // keep blocks square-orthogonal when A is orthogonal — the SVD
            // provides exactly `rank` directions, and rank == idxs.len()
            // whenever A ∈ GS (Prop. 1).
            for (t, &i) in idxs.iter().enumerate().take(rank) {
                let lj = spec.p.sigma[i] % b_l2;
                let ri = i % b_r1;
                for p in 0..b_l1 {
                    l.blocks[k1][(p, lj)] = uf[(p, t)];
                }
                for q in 0..b_r2 {
                    r.blocks[k2][(ri, q)] = vf[(q, t)];
                }
            }
        }
    }
    GsMatrix::new(spec.clone(), l, r)
}

/// Theorem 1, fully constructive: given an *orthogonal* `A` that lies in
/// `GS(P_L,P,P_R)` (square blocks), return a member whose `L`/`R` blocks
/// are each orthogonal and whose dense form equals `A`. The proof's QR
/// trick is realized via the orthonormal-U skeleton; we then verify and
/// re-orthonormalize L for numerical hygiene.
pub fn orthogonal_representation(a: &Mat, spec: &GsSpec) -> GsMatrix {
    let mut g = skeleton_orthonormal(a, spec);
    // Numerical polish: L blocks should already be orthogonal; snap them
    // with QR so downstream orthogonality checks see exact structure.
    for blk in &mut g.l.blocks {
        let (q, rr) = qr::qr(blk);
        // Keep orientation: Q·sign(diag(R)).
        let mut qq = q;
        for j in 0..rr.cols {
            if rr[(j, j)] < 0.0 {
                for i in 0..qq.rows {
                    qq[(i, j)] = -qq[(i, j)];
                }
            }
        }
        *blk = qq;
    }
    g
}

/// Squared Frobenius distance from `a` to the class (via the projection).
pub fn distance_to_class(a: &Mat, spec: &GsSpec) -> f64 {
    project(a, spec).to_dense().fro_dist(a)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gs::perm::{perm_kn, Perm};
    use crate::util::{prop, rng::Rng};

    fn gsoft_like_spec(rng: &mut Rng) -> GsSpec {
        let b = [2usize, 3, 4][rng.below(3)];
        let r = [2usize, 3, 4][rng.below(3)];
        GsSpec::gsoft(b * r, b)
    }

    #[test]
    fn projection_is_identity_on_members() {
        prop::check("π(A) = A for A ∈ GS", 121, |rng| {
            let spec = gsoft_like_spec(rng);
            let a = spec.random_member(1.0, rng);
            let dense = a.to_dense();
            let proj = project(&dense, &spec);
            assert!(
                proj.to_dense().fro_dist(&dense) < 1e-8,
                "projection must reproduce members exactly"
            );
        });
    }

    #[test]
    fn projection_is_idempotent() {
        prop::check("π(π(A)) = π(A)", 122, |rng| {
            let spec = gsoft_like_spec(rng);
            let a = Mat::randn(spec.m(), spec.n(), 1.0, rng);
            let p1 = project(&a, &spec).to_dense();
            let p2 = project(&p1, &spec).to_dense();
            assert!(p1.fro_dist(&p2) < 1e-8);
        });
    }

    #[test]
    fn projection_beats_random_members() {
        // argmin property (spot check): no random member of the class gets
        // closer to A than π(A).
        prop::check("||A - π(A)|| ≤ ||A - B|| for B ∈ GS", 123, |rng| {
            let spec = gsoft_like_spec(rng);
            let a = Mat::randn(spec.m(), spec.n(), 1.0, rng);
            let best = project(&a, &spec).to_dense().fro_dist(&a);
            for _ in 0..5 {
                let b = spec.random_member(1.0, rng);
                assert!(best <= b.to_dense().fro_dist(&a) + 1e-9);
            }
        });
    }

    #[test]
    fn projection_beats_perturbed_projection() {
        // Stronger local-optimality probe: perturbing the projected factors
        // cannot reduce the distance (first-order stationarity).
        prop::check("π(A) locally optimal", 124, |rng| {
            let spec = gsoft_like_spec(rng);
            let a = Mat::randn(spec.m(), spec.n(), 1.0, rng);
            let proj = project(&a, &spec);
            let best = proj.to_dense().fro_dist(&a);
            for scale in [1e-2, 1e-1] {
                let mut pert = proj.clone();
                for blk in pert.l.blocks.iter_mut().chain(pert.r.blocks.iter_mut()) {
                    let noise = Mat::randn(blk.rows, blk.cols, scale, rng);
                    *blk = &*blk + &noise;
                }
                assert!(pert.to_dense().fro_dist(&a) >= best - 1e-7);
            }
        });
    }

    #[test]
    fn theorem1_orthogonal_members_get_orthogonal_blocks() {
        // Theorem 1: every orthogonal member of GS(P_L,P,P_R) admits a
        // representation with orthogonal blocks. Constructively recover it.
        prop::check("Thm 1 round trip", 125, |rng| {
            let b = [2usize, 4][rng.below(2)];
            let r = [2usize, 4][rng.below(2)];
            let spec = GsSpec::gsoft(b * r, b);
            let q = spec.random_orthogonal_member(rng);
            let dense = q.to_dense();
            assert!(dense.is_orthogonal(1e-8));
            let rep = orthogonal_representation(&dense, &spec);
            // (a) reproduces the matrix
            assert!(
                rep.to_dense().fro_dist(&dense) < 1e-7,
                "dist={}",
                rep.to_dense().fro_dist(&dense)
            );
            // (b) every block of L and R is orthogonal
            assert!(
                rep.blockwise_orthogonality_error() < 1e-7,
                "block orth err={}",
                rep.blockwise_orthogonality_error()
            );
        });
    }

    #[test]
    fn projection_handles_empty_blocks() {
        // Identity permutation routes nothing off-diagonal: the projection
        // of a dense matrix is its block-diagonal part.
        let mut rng = Rng::new(4);
        let d = 8;
        let spec = GsSpec::new(
            Perm::identity(d),
            Perm::identity(d),
            Perm::identity(d),
            4,
            4,
            (2, 2),
            (2, 2),
        );
        let a = Mat::randn(d, d, 1.0, &mut rng);
        let proj = project(&a, &spec).to_dense();
        for k1 in 0..4 {
            for k2 in 0..4 {
                let blk = proj.block(2 * k1, 2 * k2, 2, 2);
                if k1 == k2 {
                    assert!(blk.fro_dist(&a.block(2 * k1, 2 * k2, 2, 2)) < 1e-9);
                } else {
                    assert_eq!(blk.nnz(1e-12), 0);
                }
            }
        }
    }

    #[test]
    fn distance_decreases_with_denser_permutation() {
        // P_(r,d) routes terms into every block; identity routes only the
        // diagonal — so the class with P_(r,d) fits a random dense matrix
        // at least as well "on average". Check on a fixed seed.
        let mut rng = Rng::new(11);
        let (b, r) = (4, 4);
        let d = b * r;
        let a = Mat::randn(d, d, 1.0, &mut rng);
        let spec_dense = GsSpec::gsoft(d, b);
        let spec_diag = GsSpec::new(
            perm_kn(r, d).inverse(),
            Perm::identity(d),
            Perm::identity(d),
            r,
            r,
            (b, b),
            (b, b),
        );
        let dd = distance_to_class(&a, &spec_dense);
        let di = distance_to_class(&a, &spec_diag);
        assert!(dd < di, "dense routing {dd} vs diagonal routing {di}");
    }
}
