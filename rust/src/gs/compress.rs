//! GS matrices *without* orthogonality constraints, used for post-hoc
//! layer compression — the direction the paper's concluding remarks call
//! out ("GS-matrices without orthogonality constraints is another
//! promising direction to consider").
//!
//! Given a trained dense layer `W`, Algorithm 1 projects it onto
//! `GS(P_L, P, P_R)` at a chosen block size; the projection error is
//! exactly the energy outside the permutation-routed block-rank profile
//! (Prop. 1 + Eckart–Young per block), so we can sweep block sizes and
//! report the compression/accuracy frontier — and compare against the
//! classical rank-k SVD baseline at matched parameter budgets.

use crate::linalg::{svd, Mat};

use super::matrix::GsSpec;
use super::project::project;

/// One point on the compression frontier.
#[derive(Clone, Debug)]
pub struct CompressPoint {
    pub label: String,
    pub params: usize,
    /// `||W - Ŵ||_F / ||W||_F`.
    pub rel_error: f64,
    /// dense params / structured params.
    pub ratio: f64,
}

/// Project `w` onto the GSOFT-shaped GS class at block size `b`.
pub fn gs_point(w: &Mat, b: usize) -> CompressPoint {
    assert_eq!(w.rows, w.cols, "GSOFT-shaped compression needs square layers");
    let spec = GsSpec::gsoft(w.rows, b);
    let approx = project(w, &spec).to_dense();
    CompressPoint {
        label: format!("GS(b={b}, m=2)"),
        params: spec.param_count(),
        rel_error: approx.fro_dist(w) / w.fro_norm(),
        ratio: (w.rows * w.cols) as f64 / spec.param_count() as f64,
    }
}

/// Rank-`k` truncated-SVD baseline (`2dk` parameters on a square layer).
pub fn svd_point(w: &Mat, k: usize) -> CompressPoint {
    let (uf, vf) = svd::truncated_factors(w, k);
    let approx = uf.matmul(&vf.t());
    let params = k * (w.rows + w.cols);
    CompressPoint {
        label: format!("SVD(rank={k})"),
        params,
        rel_error: approx.fro_dist(w) / w.fro_norm(),
        ratio: (w.rows * w.cols) as f64 / params as f64,
    }
}

/// Sweep GS block sizes and matched-budget SVD ranks over one layer.
pub fn frontier(w: &Mat, blocks: &[usize]) -> Vec<CompressPoint> {
    let mut out = Vec::new();
    for &b in blocks {
        if w.rows % b != 0 {
            continue;
        }
        let gs = gs_point(w, b);
        // SVD rank matched to the same parameter budget: 2dk = params.
        let k = (gs.params / (w.rows + w.cols)).max(1);
        out.push(gs);
        out.push(svd_point(w, k));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gs::matrix::GsSpec;
    use crate::util::rng::Rng;

    #[test]
    fn exact_members_compress_losslessly() {
        let mut rng = Rng::new(1);
        let spec = GsSpec::gsoft(32, 8);
        let w = spec.random_member(1.0, &mut rng).to_dense();
        let p = gs_point(&w, 8);
        assert!(p.rel_error < 1e-7, "member must project exactly: {}", p.rel_error);
        assert_eq!(p.params, spec.param_count());
    }

    #[test]
    fn error_decreases_with_block_size() {
        // Bigger blocks => more parameters => no worse Frobenius error
        // (the classes are nested along b | b' for the same d when the
        // rank profile only grows; empirically monotone on random W).
        let mut rng = Rng::new(2);
        let w = Mat::randn(64, 64, 1.0, &mut rng);
        let e4 = gs_point(&w, 4).rel_error;
        let e8 = gs_point(&w, 8).rel_error;
        let e16 = gs_point(&w, 16).rel_error;
        assert!(e16 <= e8 + 1e-9, "{e16} vs {e8}");
        assert!(e8 <= e4 + 1e-9, "{e8} vs {e4}");
    }

    #[test]
    fn gs_beats_svd_on_gs_structured_targets() {
        // On targets that ARE block-low-rank-routed, GS wins at equal
        // budget; on generic random matrices SVD may win — we only claim
        // the structured case (that is the paper's expressivity point).
        let mut rng = Rng::new(3);
        let spec = GsSpec::gsoft(32, 4);
        let target = spec.random_member(1.0, &mut rng).to_dense();
        let gs = gs_point(&target, 4);
        let k = (gs.params / 64).max(1);
        let sv = svd_point(&target, k);
        assert!(gs.rel_error < sv.rel_error * 0.5, "{:?} vs {:?}", gs, sv);
    }

    #[test]
    fn frontier_is_well_formed() {
        let mut rng = Rng::new(4);
        let w = Mat::randn(32, 32, 1.0, &mut rng);
        let pts = frontier(&w, &[4, 8, 16, 5]); // 5 is skipped (32 % 5 != 0)
        assert_eq!(pts.len(), 6);
        for p in &pts {
            assert!(p.rel_error.is_finite() && p.rel_error >= 0.0);
            assert!(p.ratio >= 1.0);
        }
    }
}
