//! Theorem 2 / Figure 5: density analysis of GS chains via the
//! information-transmission framework.
//!
//! The support of a product of structured factors is computed exactly with
//! bitset boolean matrices: entry `(i, j)` of the product can be nonzero
//! iff a path connects input node `j` to output node `i` through the
//! factor graph. We use this to verify
//! `m = 1 + ⌈log_b r⌉` (GS with `P_(k,br)`) against the butterfly's
//! `m = 1 + ⌈log_2 r⌉`, and the lower-bound half of Theorem 2 (fan-out per
//! factor is at most `b`, so fewer factors cannot reach all `d` nodes).

use super::perm::{perm_kn, Perm};
use crate::util::rng::Rng;

/// Dense boolean matrix with bitset rows (64 columns per word).
#[derive(Clone, Debug, PartialEq)]
pub struct BitMatrix {
    pub n: usize,
    words_per_row: usize,
    rows: Vec<u64>,
}

impl BitMatrix {
    pub fn zeros(n: usize) -> BitMatrix {
        let wpr = n.div_ceil(64);
        BitMatrix {
            n,
            words_per_row: wpr,
            rows: vec![0; n * wpr],
        }
    }

    pub fn identity(n: usize) -> BitMatrix {
        let mut m = BitMatrix::zeros(n);
        for i in 0..n {
            m.set(i, i);
        }
        m
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize) {
        self.rows[i * self.words_per_row + j / 64] |= 1u64 << (j % 64);
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> bool {
        self.rows[i * self.words_per_row + j / 64] & (1u64 << (j % 64)) != 0
    }

    fn row(&self, i: usize) -> &[u64] {
        &self.rows[i * self.words_per_row..(i + 1) * self.words_per_row]
    }

    /// Support of a block-diagonal matrix with `k` blocks of `br×bc`.
    pub fn block_diag(k: usize, br: usize, bc: usize) -> BitMatrix {
        let n = k * br;
        assert_eq!(n, k * br);
        let mut m = BitMatrix {
            n: k * br,
            words_per_row: (k * bc).div_ceil(64),
            rows: vec![0; k * br * (k * bc).div_ceil(64)],
        };
        // Note: rectangular support matrices share the `n`-rows/`cols`
        // bookkeeping through words_per_row; we only use square ones in
        // the experiments, where n == k*br == k*bc.
        for blk in 0..k {
            for i in 0..br {
                for j in 0..bc {
                    m.rows[(blk * br + i) * m.words_per_row + (blk * bc + j) / 64] |=
                        1u64 << ((blk * bc + j) % 64);
                }
            }
        }
        m
    }

    /// Permute rows: row `i` lands at `sigma(i)` (matches `Perm::apply_rows`).
    pub fn permute_rows(&self, p: &Perm) -> BitMatrix {
        assert_eq!(p.n(), self.n);
        let mut out = BitMatrix::zeros(self.n);
        out.words_per_row = self.words_per_row;
        out.rows = vec![0; self.rows.len()];
        for i in 0..self.n {
            let dst = p.sigma[i];
            let src_row = self.row(i).to_vec();
            out.rows[dst * self.words_per_row..(dst + 1) * self.words_per_row]
                .copy_from_slice(&src_row);
        }
        out
    }

    /// Boolean matrix product `self · other` (path composition).
    pub fn multiply(&self, other: &BitMatrix) -> BitMatrix {
        assert_eq!(self.n, other.n, "support product requires square factors");
        let mut out = BitMatrix::zeros(self.n);
        for i in 0..self.n {
            // out.row(i) = OR over k in self.row(i) of other.row(k)
            let mut acc = vec![0u64; out.words_per_row];
            let srow = self.row(i);
            for (w, &word) in srow.iter().enumerate() {
                let mut bits = word;
                while bits != 0 {
                    let b = bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    let k = w * 64 + b;
                    for (a, &o) in acc.iter_mut().zip(other.row(k).iter()) {
                        *a |= o;
                    }
                }
            }
            out.rows[i * out.words_per_row..(i + 1) * out.words_per_row]
                .copy_from_slice(&acc);
        }
        out
    }

    /// Number of set bits.
    pub fn nnz(&self) -> usize {
        self.rows.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Fully dense?
    pub fn is_dense(&self) -> bool {
        self.nnz() == self.n * self.n
    }

    /// Fill fraction in `[0,1]`.
    pub fn fill(&self) -> f64 {
        self.nnz() as f64 / (self.n * self.n) as f64
    }
}

/// Which permutation family a density experiment uses between factors.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PermFamily {
    /// `P_i = P_(r, d)` — the paper's choice (Definition 5.2).
    GsKn,
    /// Butterfly strides (BOFT): factor `i ≥ 1` mixes block pairs at
    /// block-stride `2^{i-1}`.
    Butterfly,
    /// Identity permutations (pure OFT stacking — stays block diagonal).
    Identity,
    /// Random permutations, re-drawn per factor (needs an RNG seed).
    Random(u64),
}

/// Support of an `m`-factor chain on dimension `d = r·b` under the given
/// permutation family.
pub fn chain_support(d: usize, b: usize, m: usize, family: PermFamily) -> BitMatrix {
    assert!(d % b == 0);
    let r = d / b;
    let block = BitMatrix::block_diag(r, b, b);
    let mut rng = match family {
        PermFamily::Random(seed) => Some(Rng::new(seed)),
        _ => None,
    };
    let mut acc: Option<BitMatrix> = None;
    for i in 0..m {
        let factor = match family {
            PermFamily::GsKn => {
                if i == 0 {
                    block.clone()
                } else {
                    // B · P — support of B with columns permuted = permute
                    // rows of B^T... equivalently support(B·P)[x, y] =
                    // support(B)[x, σ(y)]; implemented as row-permute of the
                    // transpose-free form: B·P = (rows of P^T picked) — use
                    // identity: supp(B·P) = supp(B) · supp(P).
                    let p = support_of_perm(&perm_kn(r, d));
                    block.multiply(&p)
                }
            }
            PermFamily::Identity => block.clone(),
            PermFamily::Butterfly => {
                if i == 0 {
                    block.clone()
                } else {
                    let stride = 1usize << (i - 1);
                    if 2 * stride > r {
                        // Past full depth the butterfly repeats its largest
                        // stride (keeps the sweep well-defined).
                        butterfly_support(r, b, r / 2)
                    } else {
                        butterfly_support(r, b, stride)
                    }
                }
            }
            PermFamily::Random(_) => {
                let p = Perm::random(d, rng.as_mut().unwrap());
                block.multiply(&support_of_perm(&p))
            }
        };
        acc = Some(match acc {
            None => factor,
            Some(a) => factor.multiply(&a),
        });
    }
    acc.unwrap()
}

fn support_of_perm(p: &Perm) -> BitMatrix {
    let mut m = BitMatrix::zeros(p.n());
    for (i, &s) in p.sigma.iter().enumerate() {
        m.set(s, i);
    }
    m
}

/// Support of one butterfly factor: block `p` connects to blocks `p` and
/// `p ⊕ stride`.
fn butterfly_support(r: usize, b: usize, stride: usize) -> BitMatrix {
    let d = r * b;
    let mut m = BitMatrix::zeros(d);
    for blk in 0..r {
        for other in [blk, blk ^ stride] {
            if other >= r {
                continue;
            }
            for i in 0..b {
                for j in 0..b {
                    m.set(blk * b + i, other * b + j);
                }
            }
        }
    }
    m
}

/// `1 + ⌈log_b r⌉` — factors needed by GS chains (Theorem 2).
pub fn gs_min_factors(b: usize, r: usize) -> usize {
    1 + ceil_log(b, r)
}

/// `1 + ⌈log_2 r⌉` — factors needed by block butterfly chains (BOFT).
pub fn butterfly_min_factors(r: usize) -> usize {
    1 + ceil_log(2, r)
}

/// `⌈log_base x⌉` computed exactly in integers.
pub fn ceil_log(base: usize, x: usize) -> usize {
    assert!(base >= 2 && x >= 1);
    let mut m = 0;
    let mut reach = 1usize;
    while reach < x {
        reach = reach.saturating_mul(base);
        m += 1;
    }
    m
}

/// Empirical minimal `m` for density of a chain family (sweeps m upward).
pub fn empirical_min_factors(d: usize, b: usize, family: PermFamily, max_m: usize) -> Option<usize> {
    (1..=max_m).find(|&m| chain_support(d, b, m, family).is_dense())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bitmatrix_product_matches_paths() {
        // Two explicit factors: chain 0→1→2.
        let mut a = BitMatrix::zeros(3);
        a.set(1, 0);
        let mut b = BitMatrix::zeros(3);
        b.set(2, 1);
        let c = b.multiply(&a);
        assert!(c.get(2, 0));
        assert_eq!(c.nnz(), 1);
    }

    #[test]
    fn block_diag_support() {
        let m = BitMatrix::block_diag(3, 2, 2);
        assert_eq!(m.nnz(), 3 * 4);
        assert!(m.get(0, 1) && m.get(1, 0) && !m.get(0, 2));
    }

    #[test]
    fn theorem2_gs_density_formula_exact() {
        // For every (b, r) grid point the empirical minimal m equals
        // 1 + ceil(log_b r) — both halves of Theorem 2.
        for (b, r) in [(2, 2), (2, 4), (2, 8), (4, 4), (4, 16), (3, 9), (4, 2), (8, 4)] {
            let d = b * r;
            let predicted = gs_min_factors(b, r);
            let measured =
                empirical_min_factors(d, b, PermFamily::GsKn, predicted + 2).unwrap();
            assert_eq!(measured, predicted, "b={b} r={r}");
            // Lower bound: m-1 factors are NOT dense.
            if predicted > 1 {
                assert!(!chain_support(d, b, predicted - 1, PermFamily::GsKn).is_dense());
            }
        }
    }

    #[test]
    fn butterfly_density_formula_exact() {
        for (b, r) in [(2, 4), (2, 8), (4, 4), (4, 8), (8, 2)] {
            let d = b * r;
            let predicted = butterfly_min_factors(r);
            let measured =
                empirical_min_factors(d, b, PermFamily::Butterfly, predicted + 2).unwrap();
            assert_eq!(measured, predicted, "b={b} r={r}");
        }
    }

    #[test]
    fn gs_never_needs_more_than_butterfly() {
        for (b, r) in [(4, 16), (8, 64), (16, 16), (32, 32)] {
            assert!(gs_min_factors(b, r) <= butterfly_min_factors(r), "b={b} r={r}");
        }
        // Paper's §5.2 worked example: d=1024, b=32 → butterfly 6, GS 2.
        assert_eq!(butterfly_min_factors(32), 6);
        assert_eq!(gs_min_factors(32, 32), 2);
    }

    #[test]
    fn identity_never_densifies() {
        for m in 1..5 {
            let s = chain_support(16, 4, m, PermFamily::Identity);
            assert_eq!(s.nnz(), 4 * 16); // stays block diagonal
        }
    }

    #[test]
    fn theorem2_lower_bound_holds_for_random_permutations() {
        // "any permutations": random P_i cannot beat the fan-out bound
        // b^m; check several draws below the threshold stay non-dense.
        for seed in 0..5 {
            let (b, r) = (2, 8);
            let d = b * r;
            let need = gs_min_factors(b, r); // 4
            for m in 1..need {
                let s = chain_support(d, b, m, PermFamily::Random(seed));
                assert!(
                    !s.is_dense(),
                    "m={m} < {need} must not be dense (seed={seed})"
                );
            }
        }
    }

    #[test]
    fn fanout_is_exactly_b_power_m_before_saturation() {
        // Appendix D: each input reaches exactly b^m outputs (no
        // collisions) with the P_(k,n) wiring, until saturation at d.
        let (b, r) = (2, 8);
        let d = b * r;
        for m in 1..=4 {
            let s = chain_support(d, b, m, PermFamily::GsKn);
            let expected = (b as u64).pow(m as u32).min(d as u64) as usize;
            for j in 0..d {
                let reach = (0..d).filter(|&i| s.get(i, j)).count();
                assert_eq!(reach, expected, "m={m} col={j}");
            }
        }
    }
}
