//! Appendix C: the relationship between Monarch matrices and the GS class.
//!
//! (Generalized) Monarch matrices are `P_1 L P_2 R` — a special case of
//! `GS(P_1, P_2, I)` with the *hard coupling* `k_L = b_R¹` and
//! `k_R = b_L²`. For square matrices with square blocks this forces
//! `k_L · k_R = n`, which rules out many practically useful
//! configurations (e.g. two factors with equally many small blocks under
//! a low parameter budget). GS drops the coupling.

use super::matrix::GsSpec;

/// Does this spec satisfy the Monarch structural coupling
/// `k_L = b_R¹ ∧ k_R = b_L²`?
pub fn is_monarch_expressible(spec: &GsSpec) -> bool {
    spec.k_l == spec.b_r.0 && spec.k_r == spec.b_l.1
}

/// For square `d×d` with square `b×b` blocks and `r` blocks per factor
/// (the orthogonal fine-tuning shape): Monarch requires `b = k_L = k_R`,
/// i.e. `r = b` and hence `d = b²`. Returns whether `(d, b)` is Monarch-
/// representable in that shape.
pub fn square_config_is_monarch(d: usize, b: usize) -> bool {
    d % b == 0 && d / b == b
}

/// Order-p Monarch (Fu et al. 2023) side constraint: dimensions must be
/// perfect p-th powers `a^p`.
pub fn order_p_monarch_dim_ok(n: usize, p: u32) -> bool {
    if p == 0 {
        return false;
    }
    let a = (n as f64).powf(1.0 / p as f64).round() as usize;
    (a.saturating_sub(1)..=a + 1).any(|c| c.checked_pow(p).map(|v| v == n).unwrap_or(false))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gsoft_specs_usually_escape_monarch() {
        // Paper App. C: e.g. stacking two factors with 4 blocks each on
        // n = 1024 is impossible for Monarch (needs k_L·k_R = n).
        let spec = GsSpec::gsoft(1024, 256); // r = 4 blocks of 256
        assert!(!is_monarch_expressible(&spec));
        // b = 8, r = 128 on d = 1024: also not Monarch (b_R=8 ≠ k_L=128).
        assert!(!is_monarch_expressible(&GsSpec::gsoft(1024, 8)));
    }

    #[test]
    fn sqrt_config_is_monarch() {
        // d = b² is the one square-block configuration Monarch captures.
        let spec = GsSpec::gsoft(1024, 32); // r = 32 = b
        assert!(is_monarch_expressible(&spec));
        assert!(square_config_is_monarch(1024, 32));
        assert!(!square_config_is_monarch(1024, 8));
        assert!(!square_config_is_monarch(1024, 256));
    }

    #[test]
    fn order_p_dims() {
        assert!(order_p_monarch_dim_ok(64, 2)); // 8²
        assert!(order_p_monarch_dim_ok(64, 3)); // 4³
        assert!(order_p_monarch_dim_ok(64, 6)); // 2⁶
        assert!(!order_p_monarch_dim_ok(768, 2));
        assert!(!order_p_monarch_dim_ok(768, 3));
        assert!(order_p_monarch_dim_ok(729, 3)); // 9³
    }
}
