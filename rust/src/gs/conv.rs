//! §6.3 — GS orthogonal convolutions, exact matrix view.
//!
//! Equation (2): a multichannel 2-D convolution is the block matrix whose
//! `(i, j)` block is the doubly-Toeplitz matrix of the scalar convolution
//! between input channel `j` and output channel `i`. This module builds
//! that matrix exactly (small sizes) so we can verify, in Rust and
//! independently of the JAX stack:
//!   * grouped convolution  ⇔  block-diagonal structure of Eq. (2),
//!   * `ChShuffle`          ⇔  a permutation matrix on `vec(X)`,
//!   * `L = M - ConvTranspose(M)` ⇔ skew-symmetric Eq. (2) matrix,
//!   * convolution exponential   ⇔ orthogonal Jacobian (SOC / GS-SOC).

use crate::linalg::Mat;
use crate::util::rng::Rng;

use super::perm::Perm;

/// A conv kernel `[c_out][c_in][k][k]` with odd `k`, zero ("same") padding.
#[derive(Clone, Debug)]
pub struct ConvKernel {
    pub c_out: usize,
    pub c_in: usize,
    pub k: usize,
    /// Row-major `[c_out, c_in, k, k]`.
    pub w: Vec<f64>,
}

impl ConvKernel {
    pub fn zeros(c_out: usize, c_in: usize, k: usize) -> ConvKernel {
        assert!(k % 2 == 1, "same-padded conv needs odd kernel");
        ConvKernel {
            c_out,
            c_in,
            k,
            w: vec![0.0; c_out * c_in * k * k],
        }
    }

    pub fn randn(c_out: usize, c_in: usize, k: usize, std: f64, rng: &mut Rng) -> ConvKernel {
        let mut c = ConvKernel::zeros(c_out, c_in, k);
        for v in c.w.iter_mut() {
            *v = rng.normal() * std;
        }
        c
    }

    #[inline]
    pub fn at(&self, o: usize, i: usize, p: usize, q: usize) -> f64 {
        self.w[((o * self.c_in + i) * self.k + p) * self.k + q]
    }

    #[inline]
    pub fn at_mut(&mut self, o: usize, i: usize, p: usize, q: usize) -> &mut f64 {
        &mut self.w[((o * self.c_in + i) * self.k + p) * self.k + q]
    }

    /// The paper's `ConvTranspose`: `M'_{i,j,p,q} = M_{j,i,k-1-p,k-1-q}`.
    pub fn conv_transpose(&self) -> ConvKernel {
        let mut out = ConvKernel::zeros(self.c_in, self.c_out, self.k);
        for o in 0..self.c_out {
            for i in 0..self.c_in {
                for p in 0..self.k {
                    for q in 0..self.k {
                        *out.at_mut(i, o, self.k - 1 - p, self.k - 1 - q) =
                            self.at(o, i, p, q);
                    }
                }
            }
        }
        out
    }

    /// SOC parametrization: `L = M - ConvTranspose(M)` (requires
    /// `c_in == c_out`); makes Eq. (2) skew-symmetric.
    pub fn skew_symmetrize(&self) -> ConvKernel {
        assert_eq!(self.c_in, self.c_out);
        let t = self.conv_transpose();
        let mut out = self.clone();
        for (a, b) in out.w.iter_mut().zip(t.w.iter()) {
            *a -= b;
        }
        out
    }

    /// Eq. (2): materialize the `(c_out·h·w) × (c_in·h·w)` matrix of the
    /// same-padded convolution on an `h×w` grid. `vec` is row-major
    /// `[channel, row, col]`.
    pub fn to_matrix(&self, h: usize, w: usize) -> Mat {
        let half = (self.k - 1) / 2;
        let mut m = Mat::zeros(self.c_out * h * w, self.c_in * h * w);
        for o in 0..self.c_out {
            for i in 0..self.c_in {
                for y in 0..h {
                    for x in 0..w {
                        // output (o, y, x) = Σ_{p,q} K[o,i,p,q] · X[i, y+p-half, x+q-half]
                        for p in 0..self.k {
                            for q in 0..self.k {
                                let yy = y as isize + p as isize - half as isize;
                                let xx = x as isize + q as isize - half as isize;
                                if yy < 0 || xx < 0 || yy >= h as isize || xx >= w as isize {
                                    continue;
                                }
                                let row = (o * h + y) * w + x;
                                let col = (i * h + yy as usize) * w + xx as usize;
                                m[(row, col)] += self.at(o, i, p, q);
                            }
                        }
                    }
                }
            }
        }
        m
    }

    /// Direct convolution (same padding) of `x: [c_in, h, w]`.
    pub fn conv(&self, x: &[f64], h: usize, w: usize) -> Vec<f64> {
        assert_eq!(
            x.len(),
            self.c_in * h * w,
            "conv shape mismatch: input has {} elements, kernel expects c_in·h·w = {}·{}·{} = {}",
            x.len(),
            self.c_in,
            h,
            w,
            self.c_in * h * w
        );
        let half = (self.k - 1) / 2;
        let mut y = vec![0.0; self.c_out * h * w];
        for o in 0..self.c_out {
            for i in 0..self.c_in {
                for yy in 0..h {
                    for xx in 0..w {
                        let mut acc = 0.0;
                        for p in 0..self.k {
                            for q in 0..self.k {
                                let sy = yy as isize + p as isize - half as isize;
                                let sx = xx as isize + q as isize - half as isize;
                                if sy < 0 || sx < 0 || sy >= h as isize || sx >= w as isize {
                                    continue;
                                }
                                acc += self.at(o, i, p, q)
                                    * x[(i * h + sy as usize) * w + sx as usize];
                            }
                        }
                        y[(o * h + yy) * w + xx] += acc;
                    }
                }
            }
        }
        y
    }

    /// Zero out cross-group couplings: `groups` grouped convolution
    /// (requires `groups | c_in` and `groups | c_out`).
    pub fn grouped(&self, groups: usize) -> ConvKernel {
        assert!(self.c_in % groups == 0 && self.c_out % groups == 0);
        let gi = self.c_in / groups;
        let go = self.c_out / groups;
        let mut out = self.clone();
        for o in 0..self.c_out {
            for i in 0..self.c_in {
                if o / go != i / gi {
                    for p in 0..self.k {
                        for q in 0..self.k {
                            *out.at_mut(o, i, p, q) = 0.0;
                        }
                    }
                }
            }
        }
        out
    }
}

/// Channel shuffle as a permutation on `vec(X)` for `[c, h, w]` tensors:
/// channel `i` moves to `chperm.sigma[i]`, spatial layout unchanged.
pub fn channel_shuffle_perm(chperm: &Perm, h: usize, w: usize) -> Perm {
    let c = chperm.n();
    let hw = h * w;
    let mut sigma = vec![0usize; c * hw];
    for i in 0..c {
        let dst = chperm.sigma[i];
        for s in 0..hw {
            sigma[i * hw + s] = dst * hw + s;
        }
    }
    Perm::from_sigma(sigma)
}

/// Convolution exponential `L ⋆_e X = X + L⋆X/1! + L⋆²X/2! + …`
/// (Definition 6.1), truncated at `terms` Taylor terms.
pub fn conv_exp(kernel: &ConvKernel, x: &[f64], h: usize, w: usize, terms: usize) -> Vec<f64> {
    assert_eq!(
        kernel.c_in, kernel.c_out,
        "conv_exp needs a square kernel (c_in {} vs c_out {})",
        kernel.c_in, kernel.c_out
    );
    assert_eq!(
        x.len(),
        kernel.c_in * h * w,
        "conv_exp shape mismatch: input has {} elements, kernel expects c_in·h·w = {}·{}·{} = {}",
        x.len(),
        kernel.c_in,
        h,
        w,
        kernel.c_in * h * w
    );
    let mut acc = x.to_vec();
    let mut term = x.to_vec();
    let mut fact = 1.0;
    for t in 1..=terms {
        term = kernel.conv(&term, h, w);
        fact *= t as f64;
        for (a, b) in acc.iter_mut().zip(term.iter()) {
            *a += b / fact;
        }
    }
    acc
}

/// Dense matrix exponential by scaling-and-squaring Taylor (small sizes).
pub fn mat_exp(a: &Mat, terms: usize) -> Mat {
    assert_eq!(a.rows, a.cols);
    // Scale down so the series converges fast, then square back.
    let norm = a.max_abs() * a.rows as f64;
    let squarings = if norm > 0.5 {
        (norm / 0.5).log2().ceil().max(0.0) as usize
    } else {
        0
    };
    let scaled = a.scale(1.0 / (1u64 << squarings) as f64);
    let mut acc = Mat::eye(a.rows);
    let mut term = Mat::eye(a.rows);
    let mut fact = 1.0;
    for t in 1..=terms {
        term = term.matmul(&scaled);
        fact *= t as f64;
        acc = &acc + &term.scale(1.0 / fact);
    }
    for _ in 0..squarings {
        acc = acc.matmul(&acc);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gs::perm::{perm_kn, perm_paired};
    use crate::util::prop;

    #[test]
    fn eq2_matrix_matches_direct_convolution() {
        prop::check("Eq 2: vec(L ⋆ X) = M vec(X)", 131, |rng| {
            let c_in = prop::size_in(rng, 1, 3);
            let c_out = prop::size_in(rng, 1, 3);
            let (h, w) = (prop::size_in(rng, 2, 4), prop::size_in(rng, 2, 4));
            let kern = ConvKernel::randn(c_out, c_in, 3, 1.0, rng);
            let x: Vec<f64> = (0..c_in * h * w).map(|_| rng.normal()).collect();
            let direct = kern.conv(&x, h, w);
            let via_mat = kern.to_matrix(h, w).matvec(&x);
            for (a, b) in direct.iter().zip(via_mat.iter()) {
                assert!((a - b).abs() < 1e-10);
            }
        });
    }

    #[test]
    fn grouped_conv_is_block_diagonal_in_eq2() {
        // The §6.3 structural claim: GrConv ⇔ block-diagonal Eq. (2).
        let mut rng = Rng::new(2);
        let kern = ConvKernel::randn(8, 8, 3, 1.0, &mut rng).grouped(4);
        let (h, w) = (3, 3);
        let m = kern.to_matrix(h, w);
        let blk = 2 * h * w; // channels per group × spatial
        for bi in 0..4 {
            for bj in 0..4 {
                if bi != bj {
                    assert_eq!(
                        m.block(bi * blk, bj * blk, blk, blk).nnz(1e-15),
                        0,
                        "cross-group block must vanish"
                    );
                }
            }
        }
    }

    #[test]
    fn skew_parametrization_gives_skew_matrix() {
        prop::check("L = M - ConvTranspose(M) ⇒ Eq2 skew", 132, |rng| {
            let c = prop::size_in(rng, 1, 3);
            let kern = ConvKernel::randn(c, c, 3, 1.0, rng).skew_symmetrize();
            let (h, w) = (3, 4);
            let m = kern.to_matrix(h, w);
            assert!(m.fro_dist(&m.t().scale(-1.0)) < 1e-10, "M = -M^T");
        });
    }

    #[test]
    fn conv_exponential_jacobian_is_orthogonal() {
        // SOC: exp of a skew matrix is orthogonal; the conv exponential is
        // the matrix exponential of the Eq. 2 matrix.
        let mut rng = Rng::new(3);
        let c = 2;
        let (h, w) = (3, 3);
        let mut kern = ConvKernel::randn(c, c, 3, 0.3, &mut rng).skew_symmetrize();
        // Keep the spectral mass small so a short Taylor series suffices
        // (SOC uses ~6 terms in practice).
        for v in kern.w.iter_mut() {
            *v *= 0.3;
        }
        let m = kern.to_matrix(h, w);
        let j = mat_exp(&m, 20);
        assert!(j.is_orthogonal(1e-8), "err={}", j.orthogonality_error());
        // conv_exp agrees with the dense exponential applied to vec(X).
        let x: Vec<f64> = (0..c * h * w).map(|_| rng.normal()).collect();
        let y1 = conv_exp(&kern, &x, h, w, 20);
        let y2 = j.matvec(&x);
        for (a, b) in y1.iter().zip(y2.iter()) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn gs_soc_layer_jacobian_is_orthogonal() {
        // Equation (3): GrExpConv2(ChShuffle2(GrExpConv1(ChShuffle1(X))))
        // has an orthogonal Jacobian = product of orthogonal factors.
        let mut rng = Rng::new(4);
        // c/groups = 4 channels per group and groups = 4: Theorem 2 says
        // two factors suffice for dense channel coupling (log_4 4 = 1).
        let c = 16;
        let groups = 4;
        let (h, w) = (2, 2);
        let mk = |k: usize, rng: &mut Rng| {
            let mut kern = ConvKernel::randn(c, c, k, 0.2, rng)
                .grouped(groups)
                .skew_symmetrize();
            for v in kern.w.iter_mut() {
                *v *= 0.4;
            }
            kern
        };
        let k1 = mk(3, &mut rng);
        let k2 = mk(1, &mut rng); // second conv is 1×1 per §6.3
        let p1 = channel_shuffle_perm(&perm_paired(groups, c), h, w);
        let p2 = channel_shuffle_perm(&perm_kn(groups, c), h, w);
        let j1 = mat_exp(&k1.to_matrix(h, w), 24);
        let j2 = mat_exp(&k2.to_matrix(h, w), 24);
        let jac = j2.matmul(&p2.to_mat()).matmul(&j1).matmul(&p1.to_mat());
        assert!(jac.is_orthogonal(1e-7), "err={}", jac.orthogonality_error());
        // Grouped factors alone are block-diagonal; with the shuffles the
        // full Jacobian mixes all channel pairs (dense channel coupling).
        let cblk = h * w;
        let mut coupled = 0;
        for ci in 0..c {
            for cj in 0..c {
                if jac.block(ci * cblk, cj * cblk, cblk, cblk).nnz(1e-12) > 0 {
                    coupled += 1;
                }
            }
        }
        assert_eq!(coupled, c * c, "all channel pairs interact (group-and-shuffle)");
    }

    #[test]
    fn channel_shuffle_is_spatially_coherent() {
        let p = channel_shuffle_perm(&perm_kn(2, 4), 2, 3);
        // Channel blocks move wholesale; spatial offset preserved.
        let hw = 6;
        for i in 0..4 {
            let dst = p.sigma[i * hw] / hw;
            for s in 0..hw {
                assert_eq!(p.sigma[i * hw + s], dst * hw + s);
            }
        }
    }

    #[test]
    fn channel_shuffle_perm_matches_plane_moves_rectangular() {
        // The vec(X) permutation must equal moving channel planes
        // wholesale — checked through Perm::apply_rows on genuinely
        // rectangular H≠W grids (row/col mixups would cancel at H=W).
        prop::check("ChShuffle perm == channel-plane relayout (H≠W)", 133, |rng| {
            let c = prop::size_in(rng, 1, 5);
            let h = prop::size_in(rng, 1, 4);
            let mut w = prop::size_in(rng, 1, 4);
            if w == h {
                w = h + 1;
            }
            let hw = h * w;
            let chperm = Perm::random(c, rng);
            let p = channel_shuffle_perm(&chperm, h, w);
            let x = Mat::randn(c * hw, prop::size_in(rng, 1, 3), 1.0, rng);
            let got = p.apply_rows(&x);
            for i in 0..c {
                for s in 0..hw {
                    for j in 0..x.cols {
                        assert_eq!(
                            got[(chperm.sigma[i] * hw + s, j)],
                            x[(i * hw + s, j)],
                            "channel {i} spatial {s} col {j}"
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn conv_transpose_is_the_adjoint() {
        // ⟨Mx, y⟩ = ⟨x, Mᵀy⟩ with Mᵀ realized by ConvTranspose — on
        // rectangular c_out≠c_in kernels and H≠W grids.
        prop::check("⟨Mx, y⟩ = ⟨x, ConvTranspose(M) y⟩", 134, |rng| {
            let c_in = prop::size_in(rng, 1, 3);
            let c_out = prop::size_in(rng, 1, 3);
            let h = prop::size_in(rng, 2, 4);
            let mut w = prop::size_in(rng, 2, 5);
            if w == h {
                w += 1;
            }
            let kern = ConvKernel::randn(c_out, c_in, 3, 1.0, rng);
            let x: Vec<f64> = (0..c_in * h * w).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..c_out * h * w).map(|_| rng.normal()).collect();
            let mx = kern.conv(&x, h, w);
            let mty = kern.conv_transpose().conv(&y, h, w);
            let lhs: f64 = mx.iter().zip(y.iter()).map(|(a, b)| a * b).sum();
            let rhs: f64 = x.iter().zip(mty.iter()).map(|(a, b)| a * b).sum();
            assert!(
                (lhs - rhs).abs() < 1e-8 * (1.0 + lhs.abs().max(rhs.abs())),
                "{lhs} vs {rhs}"
            );
        });
    }

    #[test]
    fn skew_symmetrized_kernel_is_anti_self_adjoint() {
        // L = M - ConvTranspose(M) ⇒ ⟨Lx, y⟩ = -⟨x, Ly⟩ on random inputs
        // — the operator-level face of the Eq. 2 skew-symmetry.
        prop::check("⟨Lx, y⟩ = -⟨x, Ly⟩ after skew_symmetrize", 135, |rng| {
            let c = prop::size_in(rng, 1, 3);
            let (h, w) = (3, 4);
            let kern = ConvKernel::randn(c, c, 3, 1.0, rng).skew_symmetrize();
            let x: Vec<f64> = (0..c * h * w).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..c * h * w).map(|_| rng.normal()).collect();
            let lx = kern.conv(&x, h, w);
            let ly = kern.conv(&y, h, w);
            let lhs: f64 = lx.iter().zip(y.iter()).map(|(a, b)| a * b).sum();
            let rhs: f64 = x.iter().zip(ly.iter()).map(|(a, b)| a * b).sum();
            assert!(
                (lhs + rhs).abs() < 1e-8 * (1.0 + lhs.abs().max(rhs.abs())),
                "{lhs} vs -{rhs}"
            );
        });
    }

    #[test]
    #[should_panic(expected = "conv shape mismatch")]
    fn conv_input_shape_is_a_hard_assert() {
        // Must report the offending dimensions in release builds too
        // (matching the kernel-subsystem matmul convention).
        let kern = ConvKernel::zeros(2, 3, 3);
        kern.conv(&[0.0; 10], 2, 2); // expects 3·2·2 = 12
    }

    #[test]
    #[should_panic(expected = "conv_exp shape mismatch")]
    fn conv_exp_input_shape_is_a_hard_assert() {
        let kern = ConvKernel::zeros(2, 2, 3);
        conv_exp(&kern, &[0.0; 7], 2, 2, 3); // expects 2·2·2 = 8
    }

    #[test]
    fn conv_transpose_is_involution() {
        let mut rng = Rng::new(5);
        let kern = ConvKernel::randn(3, 2, 3, 1.0, &mut rng);
        let back = kern.conv_transpose().conv_transpose();
        assert_eq!(kern.w, back.w);
    }

    #[test]
    fn mat_exp_of_zero_is_identity() {
        let e = mat_exp(&Mat::zeros(5, 5), 10);
        assert!(e.fro_dist(&Mat::eye(5)) < 1e-12);
    }
}
