//! Proposition 1: block low-rank interpretation of `GS(I, P, I)` matrices.
//!
//! A member of `GS(I, P, I)` is a `k_L × k_R` block matrix whose
//! `(k_1, k_2)` block is `Σ u_{σ(i)} v_i^T` over the indices `i` with
//! `⌊σ(i)/b_L²⌋ = k_1` and `⌊i/b_R¹⌋ = k_2` — each block is low-rank, with
//! rank bounded by how many rank-one terms the permutation routes into it.
//!
//! Note: the paper's displayed formula writes `⌊σ(i)/k_L⌋` / `⌊i/k_R⌋`, but
//! its own Figure-2 walkthrough (k_L=4, b_L=3: `A_00 = u_0 v_2^T +
//! u_2 v_4^T` requires `⌊2/3⌋ = 0` and `⌊4/6⌋ = 0`) shows the divisors are
//! the *block sizes*, not block counts; we follow the walkthrough.

use crate::linalg::Mat;

use super::matrix::{GsMatrix, GsSpec};
use super::perm::Perm;

/// The index sets of Proposition 1: `terms[k1][k2]` lists the `i` whose
/// rank-one term `u_{σ(i)} v_i^T` lands in block `(k1, k2)`.
pub fn block_terms(spec: &GsSpec) -> Vec<Vec<Vec<usize>>> {
    let b_l2 = spec.b_l.1;
    let b_r1 = spec.b_r.0;
    let mut terms = vec![vec![Vec::new(); spec.k_r]; spec.k_l];
    for i in 0..spec.p.n() {
        let k1 = spec.p.sigma[i] / b_l2;
        let k2 = i / b_r1;
        terms[k1][k2].push(i);
    }
    terms
}

/// Rank bound per block implied by `P` (the `r_{k1,k2}` of Algorithm 1):
/// the number of rank-one terms routed into each block, clipped by the
/// block dimensions.
pub fn block_ranks(spec: &GsSpec) -> Vec<Vec<usize>> {
    let cap = spec.b_l.0.min(spec.b_r.1);
    block_terms(spec)
        .iter()
        .map(|row| row.iter().map(|t| t.len().min(cap)).collect())
        .collect()
}

/// Reconstruct the dense matrix of a `GS(I, P, I)` member *via the
/// Proposition 1 formula* (sum of routed rank-one terms), rather than by
/// multiplying factors. Used to validate the proposition.
pub fn dense_via_prop1(a: &GsMatrix) -> Mat {
    let spec = &a.spec;
    assert!(
        spec.p_l.is_identity() && spec.p_r.is_identity(),
        "Proposition 1 is stated for GS(I, P, I)"
    );
    let (b_l1, b_l2) = spec.b_l;
    let (b_r1, b_r2) = spec.b_r;
    let m = spec.m();
    let n = spec.n();
    let mut out = Mat::zeros(m, n);
    // u_j: the j-th column of L (consecutive across blocks);
    // v_i^T: the i-th row of R.
    for i in 0..spec.p.n() {
        let j = spec.p.sigma[i];
        let k1 = j / b_l2; // which L block owns column j
        let k2 = i / b_r1; // which R block owns row i
        let lj = j % b_l2;
        let ri = i % b_r1;
        let lblk = &a.l.blocks[k1];
        let rblk = &a.r.blocks[k2];
        // Add u_j v_i^T into the (k1, k2) dense block.
        for p in 0..b_l1 {
            for q in 0..b_r2 {
                out[(k1 * b_l1 + p, k2 * b_r2 + q)] += lblk[(p, lj)] * rblk[(ri, q)];
            }
        }
    }
    out
}

/// Check that every block of a dense matrix `a` obeys the rank profile a
/// given `GS(I,P,I)` spec implies (numerical rank ≤ `r_{k1,k2}`).
pub fn respects_rank_profile(a: &Mat, spec: &GsSpec, tol: f64) -> bool {
    let ranks = block_ranks(spec);
    let (b_l1, b_r2) = (spec.b_l.0, spec.b_r.1);
    for k1 in 0..spec.k_l {
        for k2 in 0..spec.k_r {
            let blk = a.block(k1 * b_l1, k2 * b_r2, b_l1, b_r2);
            if blk.rank(tol) > ranks[k1][k2] {
                return false;
            }
        }
    }
    true
}

/// Convenience: a `GS(I, P, I)` spec with square blocks (`r` blocks of
/// `b×b` each side) and permutation `p`.
pub fn gs_ipi_spec(b: usize, r: usize, p: Perm) -> GsSpec {
    let d = b * r;
    assert_eq!(p.n(), d);
    GsSpec::new(
        Perm::identity(d),
        p,
        Perm::identity(d),
        r,
        r,
        (b, b),
        (b, b),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gs::blockdiag::BlockDiag;
    use crate::gs::perm::perm_kn;
    use crate::util::{prop, rng::Rng};

    fn random_ipi(rng: &mut Rng) -> GsMatrix {
        // Rectangular-block GS(I,P,I) with compatible sizes.
        let b_l2 = prop::size_in(rng, 1, 4);
        let k_l = prop::size_in(rng, 1, 4);
        let s = b_l2 * k_l;
        let divisors: Vec<usize> = (1..=s).filter(|d| s % d == 0).collect();
        let k_r = *rng.choice(&divisors);
        let b_r1 = s / k_r;
        let b_l1 = prop::size_in(rng, 1, 4);
        let b_r2 = prop::size_in(rng, 1, 4);
        let spec = GsSpec::new(
            Perm::identity(b_l1 * k_l),
            Perm::random(s, rng),
            Perm::identity(b_r2 * k_r),
            k_l,
            k_r,
            (b_l1, b_l2),
            (b_r1, b_r2),
        );
        spec.random_member(1.0, rng)
    }

    #[test]
    fn prop1_formula_matches_factor_product() {
        prop::check("Prop 1: Σ u_{σ(i)} v_i^T == L P R", 111, |rng| {
            let a = random_ipi(rng);
            let dense = a.to_dense();
            let viaprop = dense_via_prop1(&a);
            assert!(dense.fro_dist(&viaprop) < 1e-9);
        });
    }

    #[test]
    fn members_respect_rank_profile() {
        prop::check("GS member blocks have rank ≤ r_{k1,k2}", 112, |rng| {
            let a = random_ipi(rng);
            assert!(respects_rank_profile(&a.to_dense(), &a.spec, 1e-8));
        });
    }

    #[test]
    fn figure2_worked_example() {
        // Figure 2: k_L = 4 blocks of 3×3 in L; k_R = 2 blocks of 6×6 in R;
        // A_00 receives u_0 v_2^T + u_2 v_4^T when σ(2)=0, σ(4)=2 — we
        // reproduce with an explicit σ matching those routings.
        // Exactly i=2 and i=4 (both in R's block 0) route into L's column
        // block 0 (targets {0,1,2}); every other i < 6 routes elsewhere so
        // A_00 receives exactly the two terms of the figure.
        let p = Perm::from_sigma(vec![3, 4, 0, 5, 2, 6, 1, 7, 8, 9, 10, 11]);
        let spec = GsSpec::new(
            Perm::identity(12),
            p,
            Perm::identity(12),
            4,
            2,
            (3, 3),
            (6, 6),
        );
        let mut rng = Rng::new(3);
        let a = spec.random_member(1.0, &mut rng);
        let dense = a.to_dense();
        // A_00 must equal u_0 v_2^T + u_2 v_4^T.
        let u0: Vec<f64> = (0..3).map(|i| a.l.blocks[0][(i, 0)]).collect();
        let u2: Vec<f64> = (0..3).map(|i| a.l.blocks[0][(i, 2)]).collect();
        let v2: Vec<f64> = (0..6).map(|j| a.r.blocks[0][(2, j)]).collect();
        let v4: Vec<f64> = (0..6).map(|j| a.r.blocks[0][(4, j)]).collect();
        for i in 0..3 {
            for j in 0..6 {
                let expect = u0[i] * v2[j] + u2[i] * v4[j];
                assert!((dense[(i, j)] - expect).abs() < 1e-10);
            }
        }
        // And its rank is ≤ 2.
        assert!(dense.block(0, 0, 3, 6).rank(1e-9) <= 2);
    }

    #[test]
    fn perm_kn_distributes_terms_evenly() {
        // With P_(r, rb) and square b-blocks each block of the bipartite
        // routing gets the same number of terms — the "balanced" rank
        // profile that makes m = 2 dense when b ≥ r.
        for (b, r) in [(4, 4), (8, 4), (6, 3)] {
            let spec = gs_ipi_spec(b, r, perm_kn(r, b * r));
            let terms = block_terms(&spec);
            let per = b / r.min(b); // b*r indices into r*r blocks → b/r each (b ≥ r)
            for row in &terms {
                for t in row {
                    assert_eq!(t.len(), per.max(1), "b={b} r={r}");
                }
            }
        }
    }

    #[test]
    fn identity_perm_gives_block_diagonal_profile() {
        let spec = gs_ipi_spec(3, 4, Perm::identity(12));
        let ranks = block_ranks(&spec);
        for k1 in 0..4 {
            for k2 in 0..4 {
                assert_eq!(ranks[k1][k2], if k1 == k2 { 3 } else { 0 });
            }
        }
    }

    #[test]
    fn zero_rank_blocks_are_zero() {
        // Blocks that receive no terms must be exactly zero in the dense
        // matrix — the density mechanism behind Theorem 2.
        let mut rng = Rng::new(9);
        let spec = gs_ipi_spec(2, 4, Perm::identity(8));
        let a = GsMatrix::new(
            spec.clone(),
            BlockDiag::randn(4, 2, 2, 1.0, &mut rng),
            BlockDiag::randn(4, 2, 2, 1.0, &mut rng),
        );
        let dense = a.to_dense();
        for k1 in 0..4 {
            for k2 in 0..4 {
                if k1 != k2 {
                    assert_eq!(dense.block(2 * k1, 2 * k2, 2, 2).nnz(1e-14), 0);
                }
            }
        }
    }
}
