//! Higher-order GS matrices `GS(P_{m+1}, …, P_1)` of Definition 5.1:
//! `A = P_{m+1} · Π_{i=m..1} (B_i P_i)`, each `B_i` block-diagonal.
//!
//! Both the paper's recommended chains (`P_i = P_(k, br)`) and the block
//! butterfly chains used by BOFT (Remark 2: butterflies are GS chains with
//! particular permutations) are constructed here.

use crate::linalg::Mat;
use crate::util::rng::Rng;

use super::blockdiag::BlockDiag;
use super::perm::{perm_kn, Perm};

/// One `B_i P_i` stage of a GS chain.
#[derive(Clone, Debug)]
pub struct GsStage {
    pub block: BlockDiag,
    /// Applied *before* the block-diagonal factor (rightmost first).
    pub perm: Perm,
}

/// `A = P_out · (B_m P_m) ⋯ (B_1 P_1)`.
#[derive(Clone, Debug)]
pub struct GsChain {
    /// `P_{m+1}` — the final output permutation.
    pub p_out: Perm,
    /// Stages in application order: `stages[0]` is `(B_1, P_1)`.
    pub stages: Vec<GsStage>,
}

impl GsChain {
    /// Validated constructor: the Definition 5.1 chain constraint
    /// `b_i^1 · k_i = b_{i+1}^2 · k_{i+1}`, plus permutation sizes.
    pub fn new(p_out: Perm, stages: Vec<GsStage>) -> GsChain {
        assert!(!stages.is_empty());
        for w in stages.windows(2) {
            assert_eq!(
                w[0].block.rows(),
                w[1].block.cols(),
                "chain stage size mismatch"
            );
        }
        for st in &stages {
            assert_eq!(st.perm.n(), st.block.cols(), "P_i size must match B_i cols");
        }
        assert_eq!(
            p_out.n(),
            stages.last().unwrap().block.rows(),
            "P_out size must match B_m rows"
        );
        GsChain { p_out, stages }
    }

    /// Number of block-diagonal factors `m`.
    pub fn m(&self) -> usize {
        self.stages.len()
    }

    /// Input dimension.
    pub fn n(&self) -> usize {
        self.stages[0].block.cols()
    }

    /// Output dimension.
    pub fn out_dim(&self) -> usize {
        self.p_out.n()
    }

    /// Trainable parameters.
    pub fn param_count(&self) -> usize {
        self.stages.iter().map(|s| s.block.param_count()).sum()
    }

    /// Dense materialization.
    pub fn to_dense(&self) -> Mat {
        let n = self.n();
        // Apply the chain to the identity.
        self.apply(&Mat::eye(n))
    }

    /// Structured apply `A · X` — one fused group-and-shuffle kernel pass
    /// per stage, with `P_out` folded into the last stage's scatter
    /// ([`crate::kernel::chain_apply`]).
    pub fn apply(&self, x: &Mat) -> Mat {
        crate::kernel::chain_apply(self, x, crate::kernel::ctx())
    }

    /// Structured apply to a vector.
    pub fn apply_vec(&self, x: &[f64]) -> Vec<f64> {
        let mut cur = x.to_vec();
        for st in &self.stages {
            cur = st.perm.apply_vec(&cur);
            cur = st.block.matvec(&cur);
        }
        self.p_out.apply_vec(&cur)
    }

    /// Max per-block orthogonality error across all stages.
    pub fn blockwise_orthogonality_error(&self) -> f64 {
        self.stages
            .iter()
            .map(|s| s.block.blockwise_orthogonality_error())
            .fold(0.0, f64::max)
    }

    // ---- constructors for the chains the paper discusses -----------------

    /// The paper's recommended dense-forming chain (§5.1 / Theorem 2):
    /// `m` square-block stages of `r` blocks sized `b×b` on dimension
    /// `d = r·b`, with `P_1 = I` (first stage groups raw indices),
    /// `P_2 = … = P_m = P_(r,d)`, and `P_out = P_(r,d)^T` so the chain with
    /// identity blocks is the identity matrix... (for m=2 this reduces to
    /// the GSOFT `Q = P^T L P R` layout).
    pub fn gs_kn(d: usize, b: usize, m: usize, rng: &mut Rng, orthogonal: bool) -> GsChain {
        assert!(d % b == 0);
        let r = d / b;
        let p = perm_kn(r, d);
        let mut stages = Vec::new();
        for i in 0..m {
            let block = if orthogonal {
                BlockDiag::rand_orthogonal(r, b, rng)
            } else {
                BlockDiag::randn(r, b, b, 1.0, rng)
            };
            let perm = if i == 0 { Perm::identity(d) } else { p.clone() };
            stages.push(GsStage { block, perm });
        }
        // P_out chosen so identity blocks give the identity overall:
        // (P (P ... )) — with m-1 interior P's, P_out = (P^{m-1})^{-1}.
        let mut p_out = Perm::identity(d);
        for _ in 1..m {
            p_out = p_out.compose(&p);
        }
        GsChain::new(p_out.inverse(), stages)
    }

    /// Block-butterfly chain as used by BOFT (Remark 2): stage 0 is plain
    /// block-diagonal (`r` blocks of `b`); stage `i ≥ 1` mixes block pairs
    /// at block-stride `2^{i-1}`, expressed in GS form as
    /// `S^{-1} · diag(2b-blocks) · S` with `S` the stride-gather
    /// permutation. Requires `r` to be a power of two for the strided
    /// stages (as in BOFT).
    pub fn butterfly(d: usize, b: usize, m: usize, rng: &mut Rng, orthogonal: bool) -> GsChain {
        assert!(d % b == 0);
        let r = d / b;
        let mut stages = Vec::new();
        let mut pending = Perm::identity(d); // permutation to undo before next stage
        for i in 0..m {
            if i == 0 {
                let block = if orthogonal {
                    BlockDiag::rand_orthogonal(r, b, rng)
                } else {
                    BlockDiag::randn(r, b, b, 1.0, rng)
                };
                stages.push(GsStage {
                    block,
                    perm: Perm::identity(d),
                });
                continue;
            }
            let stride = 1usize << (i - 1);
            assert!(
                2 * stride <= r,
                "butterfly stage {i} needs 2·2^{} ≤ r={r} blocks",
                i - 1
            );
            let gather = butterfly_gather_perm(r, b, stride);
            let block = if orthogonal {
                BlockDiag::rand_orthogonal(r / 2, 2 * b, rng)
            } else {
                BlockDiag::randn(r / 2, 2 * b, 2 * b, 1.0, rng)
            };
            // B_i = gather^{-1} · blockdiag · gather; fold gather^{-1} into
            // the next stage's P (chain composition keeps everything in
            // GS(P_{m+1},…,P_1) form — this is exactly Remark 2).
            stages.push(GsStage {
                block,
                perm: gather.compose(&pending),
            });
            pending = gather.inverse();
        }
        GsChain::new(pending, stages)
    }
}

/// Gather permutation for a butterfly stage: reorders block indices so that
/// blocks `p` and `p ⊕ stride` (XOR on the block index) become adjacent.
fn butterfly_gather_perm(r: usize, b: usize, stride: usize) -> Perm {
    assert!(stride > 0 && 2 * stride <= r);
    // Enumerate block pairs in order; each pair (p, p^stride) with p's
    // stride-bit clear becomes the next two block slots.
    let mut order = Vec::with_capacity(r);
    for p in 0..r {
        if p & stride == 0 {
            order.push(p);
            order.push(p ^ stride);
        }
    }
    // order[slot] = source block. sigma maps source index -> destination.
    let mut sigma = vec![0usize; r * b];
    for (slot, &src) in order.iter().enumerate() {
        for j in 0..b {
            sigma[src * b + j] = slot * b + j;
        }
    }
    Perm::from_sigma(sigma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn gs_kn_identity_blocks_give_identity() {
        for (d, b, m) in [(8, 2, 2), (16, 2, 3), (27, 3, 3), (16, 4, 2)] {
            let mut rng = Rng::new(1);
            let mut chain = GsChain::gs_kn(d, b, m, &mut rng, false);
            for st in &mut chain.stages {
                st.block = BlockDiag::identity(st.block.k(), st.block.blocks[0].rows);
            }
            assert!(
                chain.to_dense().fro_dist(&Mat::eye(d)) < 1e-12,
                "d={d} b={b} m={m}"
            );
        }
    }

    #[test]
    fn chain_apply_matches_dense() {
        prop::check("chain apply == dense", 101, |rng| {
            let b = [2usize, 3][rng.below(2)];
            let r = prop::size_in(rng, 2, 4);
            let d = b * r;
            let m = prop::size_in(rng, 1, 3);
            let chain = GsChain::gs_kn(d, b, m, rng, false);
            let x = Mat::randn(d, 3, 1.0, rng);
            assert!(chain.to_dense().matmul(&x).fro_dist(&chain.apply(&x)) < 1e-9);
            let xv: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            let y1 = chain.apply_vec(&xv);
            let y2 = chain.to_dense().matvec(&xv);
            for (a, b) in y1.iter().zip(y2.iter()) {
                assert!((a - b).abs() < 1e-9);
            }
        });
    }

    #[test]
    fn orthogonal_chain_is_orthogonal() {
        prop::check("orthogonal chain", 102, |rng| {
            let b = [2usize, 4][rng.below(2)];
            let r = [2usize, 4][rng.below(2)];
            let m = prop::size_in(rng, 1, 3);
            let chain = GsChain::gs_kn(b * r, b, m, rng, true);
            let dense = chain.to_dense();
            assert!(dense.is_orthogonal(1e-8));
        });
    }

    #[test]
    fn butterfly_is_orthogonal_and_matches_dense() {
        let mut rng = Rng::new(5);
        // r = 8 blocks of b = 2, full butterfly m = 1 + log2(8) = 4.
        let chain = GsChain::butterfly(16, 2, 4, &mut rng, true);
        let dense = chain.to_dense();
        assert!(dense.is_orthogonal(1e-8));
        let x = Mat::randn(16, 2, 1.0, &mut rng);
        assert!(dense.matmul(&x).fro_dist(&chain.apply(&x)) < 1e-9);
    }

    #[test]
    fn butterfly_full_depth_is_dense_but_shallow_is_not() {
        let mut rng = Rng::new(6);
        let (d, b) = (16, 2); // r = 8 → needs m = 1 + log2 8 = 4
        let full = GsChain::butterfly(d, b, 4, &mut rng, false);
        assert_eq!(full.to_dense().nnz(1e-12), d * d);
        let shallow = GsChain::butterfly(d, b, 3, &mut rng, false);
        assert!(shallow.to_dense().nnz(1e-12) < d * d);
    }

    #[test]
    fn gs_needs_fewer_factors_than_butterfly() {
        // Headline structural claim (§5.2): with b = 4, r = 4 (d = 16), GS
        // is dense at m = 2 while butterfly still has zeros at m = 2.
        let mut rng = Rng::new(7);
        let gs = GsChain::gs_kn(16, 4, 2, &mut rng, false);
        assert_eq!(gs.to_dense().nnz(1e-12), 16 * 16);
        let bf = GsChain::butterfly(16, 4, 2, &mut rng, false);
        assert!(bf.to_dense().nnz(1e-12) < 16 * 16);
    }

    #[test]
    fn param_count_scales_with_m() {
        let mut rng = Rng::new(8);
        let c2 = GsChain::gs_kn(64, 8, 2, &mut rng, false);
        let c6 = GsChain::gs_kn(64, 8, 6, &mut rng, false);
        assert_eq!(c2.param_count(), 2 * 8 * 64);
        assert_eq!(c6.param_count(), 3 * c2.param_count());
    }
}
