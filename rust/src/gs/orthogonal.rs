//! §4 / §6.1 — the structured orthogonal parametrization: Cayley-
//! parametrized GS matrices, plus weight merging (the "no inference
//! overhead" property).

use crate::linalg::Mat;
use crate::util::rng::Rng;

use super::blockdiag::BlockDiag;
use super::matrix::{GsMatrix, GsSpec};

/// Trainable state of an orthogonal GS adapter: one unconstrained square
/// matrix per block (the Cayley pre-image `A`, trained as `K = A - Aᵀ`).
#[derive(Clone, Debug)]
pub struct OrthoGsParams {
    pub spec: GsSpec,
    pub l_params: Vec<Mat>,
    pub r_params: Vec<Mat>,
    /// Optional magnitude scaling (the paper uses scaling, not dropout).
    pub scale: f64,
}

impl OrthoGsParams {
    /// Identity initialization (all-zero Cayley pre-images ⇒ Q = I).
    pub fn identity(spec: GsSpec) -> OrthoGsParams {
        assert_eq!(spec.b_l.0, spec.b_l.1, "orthogonal GS needs square blocks");
        assert_eq!(spec.b_r.0, spec.b_r.1);
        let l = (0..spec.k_l).map(|_| Mat::zeros(spec.b_l.0, spec.b_l.0)).collect();
        let r = (0..spec.k_r).map(|_| Mat::zeros(spec.b_r.0, spec.b_r.0)).collect();
        OrthoGsParams {
            spec,
            l_params: l,
            r_params: r,
            scale: 1.0,
        }
    }

    /// Random initialization (used by tests/benches, not by fine-tuning).
    pub fn random(spec: GsSpec, std: f64, rng: &mut Rng) -> OrthoGsParams {
        let mut p = OrthoGsParams::identity(spec);
        for blk in p.l_params.iter_mut().chain(p.r_params.iter_mut()) {
            *blk = Mat::randn(blk.rows, blk.cols, std, rng);
        }
        p
    }

    /// Materialize the orthogonal member: Cayley per block.
    pub fn build(&self) -> GsMatrix {
        GsMatrix::new(
            self.spec.clone(),
            BlockDiag::cayley_from(&self.l_params),
            BlockDiag::cayley_from(&self.r_params),
        )
    }

    /// Trainable parameter count.
    pub fn param_count(&self) -> usize {
        self.spec.param_count()
    }

    /// Merge into a frozen pretrained weight: `W' = scale · Q · W⁰`
    /// (§6.1: "weights of the matrix Q can be merged with the pretrained
    /// weight W producing no inference overhead").
    pub fn merge(&self, w0: &Mat) -> Mat {
        let q = self.build();
        q.apply(w0).scale(self.scale)
    }
}

/// Double GSOFT (§6.2): two-sided adaptation `W' = Q_U W⁰ Q_V`.
#[derive(Clone, Debug)]
pub struct DoubleGsParams {
    pub q_u: OrthoGsParams,
    pub q_v: OrthoGsParams,
}

impl DoubleGsParams {
    pub fn identity(d_out: usize, d_in: usize, b: usize) -> DoubleGsParams {
        DoubleGsParams {
            q_u: OrthoGsParams::identity(GsSpec::gsoft(d_out, b)),
            q_v: OrthoGsParams::identity(GsSpec::gsoft(d_in, b)),
        }
    }

    pub fn param_count(&self) -> usize {
        self.q_u.param_count() + self.q_v.param_count()
    }

    /// `W' = Q_U W⁰ Q_V`.
    pub fn merge(&self, w0: &Mat) -> Mat {
        let qu = self.q_u.build();
        let qv = self.q_v.build();
        // Q_U (W0 Q_V): right-multiplication via (Q_Vᵀ W0ᵀ)ᵀ using the
        // structured apply on the transpose.
        let w0qv = qv.apply(&w0.t()).t();
        qu.apply(&w0qv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn identity_params_are_noop() {
        let mut rng = Rng::new(1);
        let w0 = Mat::randn(16, 5, 1.0, &mut rng);
        let p = OrthoGsParams::identity(GsSpec::gsoft(16, 4));
        assert!(p.merge(&w0).fro_dist(&w0) < 1e-10);
        let d = DoubleGsParams::identity(16, 5 * 1, 1); // b=1 divides 5
        assert!(d.merge(&w0).fro_dist(&w0) < 1e-9);
    }

    #[test]
    fn built_matrix_is_orthogonal_for_any_params() {
        prop::check("Cayley GS always orthogonal", 141, |rng| {
            let b = [2usize, 4, 8][rng.below(3)];
            let r = [2usize, 4][rng.below(2)];
            let p = OrthoGsParams::random(GsSpec::gsoft(b * r, b), 1.0, rng);
            let q = p.build().to_dense();
            assert!(q.is_orthogonal(1e-7), "err={}", q.orthogonality_error());
        });
    }

    #[test]
    fn merge_preserves_singular_values() {
        // Orthogonal fine-tuning preserves the spectrum of W (the paper's
        // §6.2 argument: Q only rotates the left singular vectors).
        prop::check("spectrum preserved", 142, |rng| {
            let p = OrthoGsParams::random(GsSpec::gsoft(8, 2), 0.7, rng);
            let w0 = Mat::randn(8, 6, 1.0, rng);
            let w1 = p.merge(&w0);
            let s0 = crate::linalg::singular_values(&w0);
            let s1 = crate::linalg::singular_values(&w1);
            for (a, b) in s0.iter().zip(s1.iter()) {
                assert!((a - b).abs() < 1e-7, "{a} vs {b}");
            }
        });
    }

    #[test]
    fn merge_equals_explicit_product() {
        // No inference overhead: merged weight equals Q_dense · W0 exactly.
        let mut rng = Rng::new(9);
        let p = OrthoGsParams::random(GsSpec::gsoft(12, 3), 0.5, &mut rng);
        let w0 = Mat::randn(12, 7, 1.0, &mut rng);
        let merged = p.merge(&w0);
        let explicit = p.build().to_dense().matmul(&w0);
        assert!(merged.fro_dist(&explicit) < 1e-9);
    }

    #[test]
    fn double_gsoft_changes_both_sides() {
        let mut rng = Rng::new(10);
        let w0 = Mat::randn(8, 8, 1.0, &mut rng);
        let mut d = DoubleGsParams::identity(8, 8, 2);
        for blk in d.q_v.l_params.iter_mut() {
            *blk = Mat::randn(2, 2, 1.0, &mut rng);
        }
        let w1 = d.merge(&w0);
        // Left singular subspace unchanged (Q_U = I), right rotated.
        assert!(w1.fro_dist(&w0) > 1e-3, "Q_V must act");
        let s0 = crate::linalg::singular_values(&w0);
        let s1 = crate::linalg::singular_values(&w1);
        for (a, b) in s0.iter().zip(s1.iter()) {
            assert!((a - b).abs() < 1e-7);
        }
    }

    #[test]
    fn scale_is_applied() {
        let mut rng = Rng::new(11);
        let mut p = OrthoGsParams::identity(GsSpec::gsoft(8, 2));
        p.scale = 0.5;
        let w0 = Mat::randn(8, 3, 1.0, &mut rng);
        assert!(p.merge(&w0).fro_dist(&w0.scale(0.5)) < 1e-10);
    }
}
