//! Permutations and the paper's `P_(k,n)` family (Definition 5.2) plus the
//! "paired" variant `σ^paired_(k,n)` from Appendix F.
//!
//! Convention (matches the paper's Proposition 1 walkthrough): a
//! permutation `σ` defines the matrix `P` with `P[σ(i), i] = 1`, i.e.
//! `(P x)[σ(i)] = x[i]` — index `i` of the input is routed to position
//! `σ(i)` of the output.

use crate::linalg::Mat;
use crate::util::rng::Rng;

/// A permutation of `{0, …, n-1}`, stored as the map `i ↦ σ(i)`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Perm {
    pub sigma: Vec<usize>,
}

impl Perm {
    /// Identity permutation.
    pub fn identity(n: usize) -> Perm {
        Perm {
            sigma: (0..n).collect(),
        }
    }

    /// Build from an explicit map, validating bijectivity.
    pub fn from_sigma(sigma: Vec<usize>) -> Perm {
        let n = sigma.len();
        let mut seen = vec![false; n];
        for &s in &sigma {
            assert!(s < n, "sigma out of range");
            assert!(!seen[s], "sigma not injective");
            seen[s] = true;
        }
        Perm { sigma }
    }

    /// Uniformly random permutation.
    pub fn random(n: usize, rng: &mut Rng) -> Perm {
        Perm {
            sigma: rng.permutation(n),
        }
    }

    pub fn n(&self) -> usize {
        self.sigma.len()
    }

    pub fn is_identity(&self) -> bool {
        self.sigma.iter().enumerate().all(|(i, &s)| i == s)
    }

    /// Inverse permutation (`P^T` as a matrix).
    pub fn inverse(&self) -> Perm {
        let mut inv = vec![0; self.n()];
        for (i, &s) in self.sigma.iter().enumerate() {
            inv[s] = i;
        }
        Perm { sigma: inv }
    }

    /// Composition: `(self ∘ other)(i) = self(other(i))` — as matrices,
    /// `P_self · P_other`.
    pub fn compose(&self, other: &Perm) -> Perm {
        assert_eq!(self.n(), other.n());
        Perm {
            sigma: other.sigma.iter().map(|&i| self.sigma[i]).collect(),
        }
    }

    /// Apply to a vector: `y[σ(i)] = x[i]`.
    pub fn apply_vec<T: Copy + Default>(&self, x: &[T]) -> Vec<T> {
        assert_eq!(x.len(), self.n());
        let mut y = vec![T::default(); x.len()];
        for (i, &xi) in x.iter().enumerate() {
            y[self.sigma[i]] = xi;
        }
        y
    }

    /// `P · A` — permute rows: row `i` of `A` lands at row `σ(i)`
    /// (kernel relayout; see [`crate::kernel::permute_rows`]).
    pub fn apply_rows(&self, a: &Mat) -> Mat {
        crate::kernel::permute_rows(self, a)
    }

    /// `A · P` — permute columns: column `σ(j)` of `A` lands at column `j`
    /// (since `P[σ(j), j] = 1`; kernel relayout, see
    /// [`crate::kernel::permute_cols`]).
    pub fn apply_cols(&self, a: &Mat) -> Mat {
        crate::kernel::permute_cols(self, a)
    }

    /// Dense matrix form.
    pub fn to_mat(&self) -> Mat {
        let n = self.n();
        let mut p = Mat::zeros(n, n);
        for (i, &s) in self.sigma.iter().enumerate() {
            p[(s, i)] = 1.0;
        }
        p
    }
}

/// `P_(k,n)` of Definition 5.2:
/// `σ(i) = (i mod k) · n/k + ⌊i/k⌋`.
///
/// Applying it is the reshape(n → n/k × k, row-major) → transpose →
/// flatten relayout; it is the permutation Monarch/GS use between the two
/// block-diagonal factors.
pub fn perm_kn(k: usize, n: usize) -> Perm {
    assert!(k > 0 && n % k == 0, "P_(k,n) requires k | n (got k={k}, n={n})");
    let stride = n / k;
    Perm {
        sigma: (0..n).map(|i| (i % k) * stride + i / k).collect(),
    }
}

/// The "paired" permutation of Appendix F:
/// `σ(i) = (⌊i/2⌋ mod k) · n/k + 2·⌊i/(2k)⌋ + (i mod 2)`.
///
/// It moves *pairs* of adjacent channels together so that the channels
/// coupled by `MaxMinPermuted` stay in the same group across `ChShuffle`.
pub fn perm_paired(k: usize, n: usize) -> Perm {
    assert!(n % 2 == 0, "paired permutation needs even n");
    assert!(k > 0 && n % k == 0 && (n / k) % 2 == 0, "paired P_(k,n) requires 2k | n");
    let stride = n / k;
    Perm {
        sigma: (0..n)
            .map(|i| ((i / 2) % k) * stride + 2 * (i / (2 * k)) + (i % 2))
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn perm_kn_matches_reshape_transpose() {
        // Def 5.2's description: reshape n into (k rows? see paper) —
        // concretely σ(i) = (i mod k)·n/k + ⌊i/k⌋ sends consecutive input
        // indices to strided outputs. Check against a literal
        // reshape-transpose for k=3, n=12.
        let p = perm_kn(3, 12);
        // y[σ(i)] = x[i] ⇔ y[j] = x[σ^{-1}(j)]; σ^{-1}(j) = (j mod 4)*3 + j/4.
        let x: Vec<usize> = (0..12).collect();
        let y = p.apply_vec(&x);
        let expected: Vec<usize> = (0..12).map(|j| (j % 4) * 3 + j / 4).collect();
        assert_eq!(y, expected);
    }

    #[test]
    fn perm_kn_inverse_is_perm_nk() {
        prop::check("P_(k,n)^{-1} = P_(n/k,n)", 71, |rng| {
            let k = [2, 3, 4, 6, 8][rng.below(5)];
            let mult = prop::size_in(rng, 1, 6);
            let n = k * mult;
            assert_eq!(perm_kn(k, n).inverse(), perm_kn(n / k, n));
        });
    }

    #[test]
    fn apply_rows_cols_match_dense() {
        prop::check("P·A and A·P match dense matmul", 72, |rng| {
            let n = prop::size_in(rng, 1, 9);
            let p = Perm::random(n, rng);
            let a = Mat::randn(n, n, 1.0, rng);
            let pd = p.to_mat();
            assert!(p.apply_rows(&a).fro_dist(&pd.matmul(&a)) < 1e-12);
            assert!(p.apply_cols(&a).fro_dist(&a.matmul(&pd)) < 1e-12);
        });
    }

    #[test]
    fn inverse_and_compose_laws() {
        prop::check("P P^{-1} = I; compose matches matmul", 73, |rng| {
            let n = prop::size_in(rng, 1, 12);
            let p = Perm::random(n, rng);
            let q = Perm::random(n, rng);
            assert!(p.compose(&p.inverse()).is_identity());
            assert!(p.inverse().compose(&p).is_identity());
            let pq = p.compose(&q);
            assert!(pq.to_mat().fro_dist(&p.to_mat().matmul(&q.to_mat())) < 1e-12);
        });
    }

    #[test]
    fn perm_matrix_is_orthogonal() {
        let mut rng = crate::util::rng::Rng::new(1);
        let p = Perm::random(17, &mut rng);
        assert!(p.to_mat().is_orthogonal(1e-12));
        // P^T = P^{-1}.
        assert!(p.to_mat().t().fro_dist(&p.inverse().to_mat()) < 1e-12);
    }

    #[test]
    fn paired_perm_keeps_pairs_adjacent() {
        // Pairs (2t, 2t+1) must land on adjacent (even, odd) positions.
        for (k, n) in [(2, 8), (4, 16), (2, 12), (4, 32)] {
            let p = perm_paired(k, n);
            for t in 0..n / 2 {
                let a = p.sigma[2 * t];
                let b = p.sigma[2 * t + 1];
                assert_eq!(a % 2, 0, "even member lands even");
                assert_eq!(b, a + 1, "pair stays adjacent");
            }
        }
    }

    #[test]
    fn paired_perm_is_valid_permutation() {
        for (k, n) in [(2, 8), (4, 16), (2, 12), (8, 32)] {
            let p = perm_paired(k, n);
            // from_sigma would panic on a non-bijection.
            let _ = Perm::from_sigma(p.sigma.clone());
        }
    }

    #[test]
    fn paired_perm_quotient_is_perm_kn() {
        // Collapsing pairs to single "super-channels" must reproduce
        // P_(k, n/2) — that is exactly why Appendix F calls it optimal for
        // information transmission.
        let (k, n) = (4, 32);
        let p = perm_paired(k, n);
        let q = perm_kn(k, n / 2);
        for t in 0..n / 2 {
            assert_eq!(p.sigma[2 * t] / 2, q.sigma[t]);
        }
    }

    #[test]
    fn fig3_examples_shapes() {
        // Figure 3 shows P_(k,12) for k ∈ {3,4,6,2}; sanity: all valid, and
        // k=1 / k=n are identities.
        for k in [3, 4, 6, 2] {
            let p = perm_kn(k, 12);
            let _ = Perm::from_sigma(p.sigma.clone());
        }
        assert!(perm_kn(1, 12).is_identity());
        assert!(perm_kn(12, 12).is_identity());
    }
}
