//! Parameter-count and factor-count accounting for the PEFT methods the
//! paper compares (§2, §5.2, Tables 1–2), used by `gsoft params-table`.

use super::density::{butterfly_min_factors, gs_min_factors};

/// Trainable parameters of one `d×d` adapter under each method.
/// `b` is the block size, `m` the number of factors, `rank` the LoRA rank.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Full fine-tuning of the `d×n` weight (here reported for `n = d`).
    Full,
    /// LoRA with rank `r`: `2 d r`.
    LoRa { rank: usize },
    /// OFT: one block-diagonal orthogonal factor, `r` blocks of `b×b`.
    Oft { block: usize },
    /// BOFT: `m` block-butterfly factors of `b×b` blocks.
    Boft { block: usize, m: usize },
    /// GSOFT: `m` (=2 in practice) block-diagonal factors of `b×b` blocks.
    Gsoft { block: usize, m: usize },
    /// Double GSOFT: GSOFT applied on both sides (each with m factors).
    DoubleGsoft { block: usize, m: usize },
}

impl Method {
    /// Dense trainable-parameter count for a `d×d` weight.
    ///
    /// Orthogonal methods are counted as stored in practice — a full `b×b`
    /// matrix per block (`K = A - Aᵀ`; the paper notes one can store only
    /// the upper triangle post-training, halving this).
    pub fn param_count(&self, d: usize) -> usize {
        match *self {
            Method::Full => d * d,
            Method::LoRa { rank } => 2 * d * rank,
            Method::Oft { block } => {
                assert!(d % block == 0);
                (d / block) * block * block // = d·b
            }
            Method::Boft { block, m } => m * (d / block) * block * block,
            Method::Gsoft { block, m } => m * (d / block) * block * block,
            Method::DoubleGsoft { block, m } => 2 * m * (d / block) * block * block,
        }
    }

    /// Upper-triangle storage count (post-training memory; paper §7.1).
    pub fn storage_count(&self, d: usize) -> usize {
        match *self {
            Method::Oft { block }
            | Method::Boft { block, .. }
            | Method::Gsoft { block, .. }
            | Method::DoubleGsoft { block, .. } => {
                // skew-symmetric: b(b-1)/2 per block
                let per_block = block * (block - 1) / 2;
                let blocks = self.param_count(d) / (block * block);
                blocks * per_block
            }
            _ => self.param_count(d),
        }
    }

    /// Factors needed to form a dense matrix at this block size (§5.2).
    pub fn factors_for_dense(&self, d: usize) -> usize {
        match *self {
            Method::Full | Method::LoRa { .. } => 1,
            Method::Oft { .. } => 1, // never dense; reported as its single factor
            Method::Boft { block, .. } => butterfly_min_factors(d / block),
            Method::Gsoft { block, .. } | Method::DoubleGsoft { block, .. } => {
                gs_min_factors(block, d / block)
            }
        }
    }

    pub fn name(&self) -> String {
        match *self {
            Method::Full => "Full".into(),
            Method::LoRa { rank } => format!("LoRA(r={rank})"),
            Method::Oft { block } => format!("OFT(b={block})"),
            Method::Boft { block, m } => format!("BOFT(b={block},m={m})"),
            Method::Gsoft { block, m } => format!("GSOFT(b={block},m={m})"),
            Method::DoubleGsoft { block, m } => format!("DoubleGSOFT(b={block},m={m})"),
        }
    }
}

/// The §5.2 worked example and its generalization: for a `d×d` dense
/// orthogonal matrix with block size `b`, the (factors, params) cost of
/// BOFT vs GSOFT.
pub fn dense_cost_comparison(d: usize, b: usize) -> ((usize, usize), (usize, usize)) {
    let r = d / b;
    let m_bf = butterfly_min_factors(r);
    let m_gs = gs_min_factors(b, r);
    let boft = Method::Boft { block: b, m: m_bf };
    let gsoft = Method::Gsoft { block: b, m: m_gs };
    ((m_bf, boft.param_count(d)), (m_gs, gsoft.param_count(d)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn section_5_2_worked_example() {
        // 1024×1024, b = 32: butterfly needs 6 factors → 6·32³ params;
        // GS needs 2 → 2·32³.
        let ((m_bf, p_bf), (m_gs, p_gs)) = dense_cost_comparison(1024, 32);
        assert_eq!(m_bf, 6);
        assert_eq!(p_bf, 6 * 32 * 32 * 32);
        assert_eq!(m_gs, 2);
        assert_eq!(p_gs, 2 * 32 * 32 * 32);
    }

    #[test]
    fn table1_param_budgets_are_comparable() {
        // Table 1 uses LoRA r=8, OFT b=16, BOFT b=8 m=2, GSOFT b=8 on
        // RoBERTa-base (hidden 768): per-layer counts should be of the
        // same order (the paper reports 1.33M–1.42M total).
        let d = 768;
        let lora = Method::LoRa { rank: 8 }.param_count(d);
        let oft = Method::Oft { block: 16 }.param_count(d);
        let boft = Method::Boft { block: 8, m: 2 }.param_count(d);
        let gsoft = Method::Gsoft { block: 8, m: 2 }.param_count(d);
        assert_eq!(lora, 2 * 768 * 8);
        assert_eq!(oft, 768 * 16);
        assert_eq!(boft, gsoft);
        assert_eq!(gsoft, 2 * 768 * 8);
        // GSOFT(b=8,m=2) == LoRA(r=8) parameter parity on square layers.
        assert_eq!(lora, gsoft);
    }

    #[test]
    fn storage_halving() {
        let m = Method::Gsoft { block: 8, m: 2 };
        let d = 64;
        // b(b-1)/2 per block vs b² per block → ratio (b-1)/(2b).
        assert_eq!(m.storage_count(d) * 2 * 8, m.param_count(d) * 7);
    }

    #[test]
    fn double_gsoft_doubles() {
        let d = 256;
        assert_eq!(
            Method::DoubleGsoft { block: 8, m: 2 }.param_count(d),
            2 * Method::Gsoft { block: 8, m: 2 }.param_count(d)
        );
    }
}
