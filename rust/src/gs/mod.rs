//! The Group-and-Shuffle matrix algebra — exact (f64) reference
//! implementations of every construction in the paper:
//!
//! - [`perm`] — `P_(k,n)` (Def. 5.2) and the paired variant (App. F)
//! - [`blockdiag`] — the `L`/`R` factors, Cayley-orthogonal blocks
//! - [`matrix`] — two-factor `GS(P_L, P, P_R)` class (Def. 3.1)
//! - [`chain`] — higher-order `GS(P_{m+1},…,P_1)` (Def. 5.1) + the block
//!   butterfly chains of BOFT expressed as GS chains (Remark 2)
//! - [`lowrank`] — Proposition 1 block low-rank structure
//! - [`project`] — Algorithm 1 projection + the Theorem 1 construction
//! - [`density`] — Theorem 2 information-transmission analysis
//! - [`monarch`] — Appendix C Monarch constraint comparison
//! - [`params`] — parameter/factor accounting (§5.2, Tables 1–2)
//! - [`conv`] — §6.3 orthogonal convolutions in exact matrix form (Eq. 2)
//! - [`orthogonal`] — Cayley-parametrized orthogonal GS + weight merging
//!
//! The f32 *training* path lives in the JAX layer (`python/compile/`) and
//! executes through [`crate::runtime`]; this module is the ground truth
//! the tests and the merge path rely on.

pub mod blockdiag;
pub mod chain;
pub mod compress;
pub mod conv;
pub mod density;
pub mod lowrank;
pub mod matrix;
pub mod monarch;
pub mod orthogonal;
pub mod params;
pub mod perm;
pub mod project;

pub use blockdiag::BlockDiag;
pub use chain::{GsChain, GsStage};
pub use matrix::{GsMatrix, GsSpec};
pub use orthogonal::{DoubleGsParams, OrthoGsParams};
pub use perm::{perm_kn, perm_paired, Perm};
pub use project::{orthogonal_representation, project};
