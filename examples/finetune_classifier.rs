//! End-to-end driver (DESIGN.md "End-to-end validation"): pretrain the
//! larger `clsbig` transformer (d=256, 4 layers, vocab 2048) on the
//! SynGLUE mixture for a few hundred steps, fine-tune it with GSOFT on a
//! downstream task, log the loss curves, evaluate, merge the adapter into
//! the base weights in Rust, and verify zero-overhead inference — all
//! layers (Pallas kernels → JAX graphs → PJRT runtime → coordinator)
//! composing on a real small workload.
//!
//! Run: `make artifacts && cargo run --release --example finetune_classifier`
//! (flags: --pretrain-steps N --steps N --eval-batches N)

use anyhow::Result;
use gsoft::coordinator::config::RunOpts;
use gsoft::coordinator::experiments::{pretrained_cls_base, table1};
use gsoft::coordinator::flatspec::FlatSpec;
use gsoft::coordinator::merge::merge_gsoft;
use gsoft::data::synglue::{Task, TaskGen};
use gsoft::runtime::{Runtime, Tensor};
use gsoft::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(&["no-cache"]);
    let mut opts = RunOpts::load("e2e", &args)?;
    if args.opt("pretrain-steps").is_none() {
        opts.pretrain_steps = 300;
    }
    if args.opt("steps").is_none() {
        opts.steps = 200;
    }

    let rt = Runtime::new(&opts.artifacts)?;
    println!("== e2e fine-tuning driver (clsbig: d=256, 4 layers) ==");
    println!("platform: {}", rt.platform());

    // Phase 1: pretrain (full fine-tune artifact) on the task mixture.
    let base = pretrained_cls_base(&rt, "clsbig", &opts)?;
    println!("pretrained base: {} parameters", base.len());

    // Phase 2: GSOFT fine-tune on the held-out target task (RTE*).
    let task = Task::Rte;
    println!(
        "fine-tuning GSOFT(b=8) on {} for {} steps…",
        task.name(),
        opts.steps
    );
    let (log, acc, state, frozen) =
        table1::finetune_once(&rt, "clsbig", "gsoft", task, &base, &opts)?;
    println!(
        "  adapter params: {}  ({:.2}% of base)",
        state.trainable.len(),
        state.trainable.len() as f64 / base.len() as f64 * 100.0
    );
    println!(
        "  loss {:.4} -> {:.4}   ({:.1} steps/s)",
        log.losses.first().copied().unwrap_or(f32::NAN),
        log.tail_loss(10),
        log.steps_per_second()
    );
    println!("  eval accuracy: {acc:.2}%");

    // Loss curve to results/ for EXPERIMENTS.md.
    std::fs::create_dir_all("results")?;
    let mut csv = String::from("step,loss\n");
    for (i, l) in log.losses.iter().enumerate() {
        csv.push_str(&format!("{i},{l}\n"));
    }
    std::fs::write("results/e2e_loss_curve.csv", &csv)?;
    println!("  wrote results/e2e_loss_curve.csv ({} points)", log.losses.len());

    // Phase 3: merge Q into the base in Rust; verify predictions match.
    let train = rt.load("clsbig_gsoft_train")?;
    let block = train.meta.extra_usize("block")?;
    let base_spec = FlatSpec::from_json(train.meta.extra.get("base_spec").unwrap())?;
    let adapter_spec = FlatSpec::from_json(train.meta.extra.get("adapter_spec").unwrap())?;
    let merged = merge_gsoft(&base, &state.trainable, &base_spec, &adapter_spec, block)?;

    let eval_gs = rt.load("clsbig_gsoft_eval")?;
    let eval_ft = rt.load("clsbig_ft_eval")?;
    let vocab = train.meta.extra_usize("vocab")?;
    let seq = train.meta.extra_usize("seq")?;
    let batch = train.meta.extra_usize("batch")?;
    let gen = TaskGen::new(task, vocab, seq);
    let mut rng = gsoft::util::rng::Rng::new(777);
    let mut mismatches = 0usize;
    let mut total = 0usize;
    for _ in 0..4 {
        let (xs, ys) = gen.batch(batch, &mut rng);
        let a = eval_gs.run(&[
            Tensor::f32(vec![state.trainable.len()], state.trainable.clone()),
            Tensor::f32(vec![frozen.len()], frozen.clone()),
            Tensor::i32(vec![batch, seq], xs.clone()),
            Tensor::i32(vec![batch], ys.clone()),
        ])?;
        let b = eval_ft.run(&[
            Tensor::f32(vec![merged.len()], merged.clone()),
            Tensor::f32(vec![1], vec![0.0]),
            Tensor::i32(vec![batch, seq], xs),
            Tensor::i32(vec![batch], ys),
        ])?;
        mismatches += a[2]
            .as_i32()?
            .iter()
            .zip(b[2].as_i32()?)
            .filter(|(x, y)| x != y)
            .count();
        total += batch;
    }
    println!("merge check: {mismatches}/{total} prediction mismatches after merging");
    anyhow::ensure!(mismatches == 0, "merged model must match adapted model");
    println!("\ne2e driver OK — loss curve logged, accuracy measured, merge verified.");
    Ok(())
}
