//! Subject-driven adaptation walkthrough (the Table-2 scenario on one
//! method): pretrain the conditional denoiser on context classes,
//! fine-tune GSOFT on a 4-shot concept, sample from the adapted model and
//! report fidelity (Concept-I) and prompt-following (Concept-T), plus an
//! ASCII rendering of a generated sample next to a true concept example.
//!
//! Run: `make artifacts && cargo run --release --example subject_adaptation`

use anyhow::Result;
use gsoft::coordinator::config::RunOpts;
use gsoft::coordinator::experiments::table2::{pretrained_dn_base, Sampler};
use gsoft::coordinator::schedule::LrSchedule;
use gsoft::coordinator::trainer::{Trainer, TrainState};
use gsoft::data::concept::{self, Encoder, CONCEPT_COND, DIM, IMG};
use gsoft::runtime::{Runtime, Tensor};
use gsoft::util::cli::Args;
use gsoft::util::rng::Rng;

fn ascii(img: &[f32]) -> String {
    let ramp = [' ', '.', ':', '+', '*', '#', '@'];
    let lo = img.iter().cloned().fold(f32::MAX, f32::min);
    let hi = img.iter().cloned().fold(f32::MIN, f32::max);
    let mut s = String::new();
    for y in 0..IMG {
        s.push_str("    ");
        for x in 0..IMG {
            let v = (img[y * IMG + x] - lo) / (hi - lo + 1e-6);
            s.push(ramp[((v * (ramp.len() - 1) as f32).round() as usize).min(ramp.len() - 1)]);
            s.push(ramp[0]); // aspect-ratio spacer
        }
        s.push('\n');
    }
    s
}

fn main() -> Result<()> {
    let args = Args::from_env(&["no-cache"]);
    let mut opts = RunOpts::load("table2", &args)?;
    if args.opt("pretrain-steps").is_none() {
        opts.pretrain_steps = 600;
    }
    if args.opt("steps").is_none() {
        opts.steps = 250;
    }
    let method = args.opt_or("method", "gsoft8").to_string();

    let rt = Runtime::new(&opts.artifacts)?;
    println!("== subject-driven adaptation ({method}) ==");
    let base = pretrained_dn_base(&rt, &opts)?;

    let train = rt.load(&format!("dn_{method}_train"))?;
    let predict = rt.load(&format!("dn_{method}_predict"))?;
    let batch = train.meta.extra_usize("batch")?;
    let tsteps = train.meta.extra_usize("tsteps")?;
    let (init, frozen) = if method == "ft" {
        (base.clone(), vec![0.0])
    } else {
        (rt.load_init(&format!("dn_{method}_adapter"))?, base.clone())
    };
    println!("adapter params: {}", init.len());

    // 4-shot concept, like DreamBooth's handful of subject photos.
    let mut data_rng = Rng::new(0xC0CE);
    let examples = concept::concept_examples(4, &mut data_rng);
    println!("\ntrue concept example:\n{}", ascii(&examples[0]));

    let trainer = Trainer::new(train, frozen.clone());
    let mut state = TrainState::new(init);
    let mut rng = Rng::new(opts.seed);
    let sched = LrSchedule::finetune(opts.lr, opts.steps);
    let ex = examples.clone();
    let log = trainer.run(&mut state, opts.steps, sched, &mut rng, |_, r| {
        let (x0, cond) = concept::finetune_batch(batch, &ex, r);
        let t: Vec<i32> = (0..batch).map(|_| r.below(tsteps) as i32).collect();
        let eps: Vec<f32> = (0..batch * DIM).map(|_| r.normal_f32(1.0)).collect();
        vec![
            Tensor::f32(vec![batch, DIM], x0),
            Tensor::i32(vec![batch], cond),
            Tensor::i32(vec![batch], t),
            Tensor::f32(vec![batch, DIM], eps),
        ]
    })?;
    println!(
        "fine-tuned {} steps: loss {:.4} -> {:.4} ({:.1} steps/s)",
        opts.steps,
        log.losses.first().copied().unwrap_or(f32::NAN),
        log.tail_loss(10),
        log.steps_per_second()
    );

    // Sample with the concept condition and with a context condition.
    let sampler = Sampler::new(predict)?;
    let encoder = Encoder::new();
    let mut gen_rng = Rng::new(0x5EED);
    let gens = sampler.sample(&state.trainable, &frozen, &vec![CONCEPT_COND; batch], &mut gen_rng)?;
    let best = gens
        .iter()
        .map(|g| {
            examples
                .iter()
                .map(|e| encoder.similarity(g, e))
                .fold(f64::MIN, f64::max)
        })
        .fold(f64::MIN, f64::max);
    let avg: f64 = gens
        .iter()
        .map(|g| {
            examples
                .iter()
                .map(|e| encoder.similarity(g, e))
                .fold(f64::MIN, f64::max)
        })
        .sum::<f64>()
        / gens.len() as f64;
    println!("\ngenerated with the concept token:\n{}", ascii(&gens[0]));
    println!("Concept-I (fidelity): avg {avg:.3}, best {best:.3}");

    let conds: Vec<i32> = (0..batch).map(|i| (i % 8) as i32).collect();
    let gens_ctx = sampler.sample(&state.trainable, &frozen, &conds, &mut gen_rng)?;
    let mut tmpl_rng = Rng::new(0x7E11);
    let ct: f64 = gens_ctx
        .iter()
        .zip(conds.iter())
        .map(|(g, &c)| {
            (0..4)
                .map(|_| encoder.similarity(g, &concept::context_image(c as usize, &mut tmpl_rng)))
                .fold(f64::MIN, f64::max)
        })
        .sum::<f64>()
        / gens_ctx.len() as f64;
    println!("Concept-T (prompt following on context classes): {ct:.3}");
    println!("\nsubject_adaptation OK");
    Ok(())
}
