//! Quickstart: the GS structured orthogonal parametrization end to end.
//!
//! 1. Exact algebra (pure Rust): build an orthogonal GS matrix, inspect
//!    its block-low-rank structure (Prop. 1 / Figs. 1–2), project a dense
//!    matrix onto the class (Algorithm 1).
//! 2. AOT path: load the `quickstart_gs_apply` artifact (Pallas kernels
//!    lowered to HLO) and verify it against the exact algebra.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use anyhow::Result;
use gsoft::gs::{lowrank, perm_kn, project, GsSpec, OrthoGsParams, Perm};
use gsoft::linalg::Mat;
use gsoft::runtime::{Runtime, Tensor};
use gsoft::util::rng::Rng;

fn main() -> Result<()> {
    let mut rng = Rng::new(42);

    // ---- 1. the GS class, exactly ---------------------------------------
    let (d, b) = (64usize, 8usize);
    let spec = GsSpec::gsoft(d, b);
    println!("GS(P^T, P_(r,{d}), I) with r = {} blocks of {b}x{b}", d / b);
    println!(
        "  trainable params: {} (dense would be {})",
        spec.param_count(),
        d * d
    );

    let params = OrthoGsParams::random(spec.clone(), 0.7, &mut rng);
    let q = params.build();
    let dense = q.to_dense();
    println!(
        "  orthogonality error ||Q^T Q - I||_F = {:.2e}",
        dense.orthogonality_error()
    );
    println!(
        "  density: {}/{} nonzeros (Theorem 2: dense at m = 2)",
        dense.nnz(1e-12),
        d * d
    );

    // Proposition 1: the block rank profile dictated by the permutation.
    let ranks = lowrank::block_ranks(&GsSpec::new(
        Perm::identity(d),
        perm_kn(d / b, d),
        Perm::identity(d),
        d / b,
        d / b,
        (b, b),
        (b, b),
    ));
    println!(
        "  Prop. 1 block-rank profile (uniform = balanced routing): r_00 = {}",
        ranks[0][0]
    );

    // Algorithm 1: project a dense matrix onto the class.
    let a = Mat::randn(d, d, 1.0, &mut rng);
    let pi_a = project(&a, &spec);
    println!(
        "  Algorithm 1: ||A - pi(A)||_F / ||A||_F = {:.3} (params {}x fewer)",
        pi_a.to_dense().fro_dist(&a) / a.fro_norm(),
        d * d / spec.param_count()
    );

    // ---- 2. the AOT kernel path ------------------------------------------
    let rt = Runtime::new("artifacts")?;
    println!("\nPJRT platform: {}", rt.platform());
    let exe = rt.load("quickstart_gs_apply")?;
    let r = exe.meta.extra_usize("r")?;
    let bb = exe.meta.extra_usize("b")?;
    let dd = exe.meta.extra_usize("d")?;
    let t = exe.meta.extra_usize("t")?;
    println!("artifact quickstart_gs_apply: d={dd}, r={r}, b={bb}, batch={t}");

    let lp: Vec<f32> = (0..r * bb * bb).map(|_| rng.normal_f32(0.5)).collect();
    let rp: Vec<f32> = (0..r * bb * bb).map(|_| rng.normal_f32(0.5)).collect();
    let x: Vec<f32> = (0..dd * t).map(|_| rng.normal_f32(1.0)).collect();
    let out = exe.run(&[
        Tensor::f32(vec![r, bb, bb], lp.clone()),
        Tensor::f32(vec![r, bb, bb], rp.clone()),
        Tensor::f32(vec![dd, t], x.clone()),
    ])?;
    let y = out[0].as_f32()?;

    // Orthogonal ⇒ column norms preserved.
    for col in 0..t.min(3) {
        let nx: f32 = (0..dd).map(|i| x[i * t + col].powi(2)).sum::<f32>().sqrt();
        let ny: f32 = (0..dd).map(|i| y[i * t + col].powi(2)).sum::<f32>().sqrt();
        println!("  column {col}: ||x|| = {nx:.4}  ||Qx|| = {ny:.4}");
    }

    // Cross-check against the exact Rust algebra (f64).
    let mut exact = OrthoGsParams::identity(GsSpec::gsoft(dd, bb));
    for (i, blk) in exact.l_params.iter_mut().enumerate() {
        *blk = Mat::from_f32(bb, bb, &lp[i * bb * bb..(i + 1) * bb * bb]);
    }
    for (i, blk) in exact.r_params.iter_mut().enumerate() {
        *blk = Mat::from_f32(bb, bb, &rp[i * bb * bb..(i + 1) * bb * bb]);
    }
    let qx = exact.build().apply(&Mat::from_f32(dd, t, &x));
    let mut max_err = 0.0f64;
    for i in 0..dd {
        for j in 0..t {
            max_err = max_err.max((qx[(i, j)] - y[i * t + j] as f64).abs());
        }
    }
    println!("  max |kernel - exact| = {max_err:.3e}");
    anyhow::ensure!(max_err < 1e-4, "kernel path must match exact algebra");
    println!("\nquickstart OK");
    Ok(())
}
