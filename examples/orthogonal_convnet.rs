//! GS orthogonal convolutions (§6.3) walkthrough: verify the structural
//! claims with the exact Rust conv algebra, then train a small GS-SOC
//! LipConvnet via the AOT path and report accuracy + certified robust
//! accuracy against plain SOC.
//!
//! Run: `make artifacts && cargo run --release --example orthogonal_convnet`

use anyhow::Result;
use gsoft::coordinator::config::RunOpts;
use gsoft::coordinator::experiments::table3;
use gsoft::gs::conv::{channel_shuffle_perm, mat_exp, ConvKernel};
use gsoft::gs::perm::perm_paired;
use gsoft::util::cli::Args;
use gsoft::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env(&["no-cache"]);
    let mut opts = RunOpts::load("table3", &args)?;
    if args.opt("steps").is_none() {
        opts.steps = 150;
    }
    if args.opt("eval-batches").is_none() {
        opts.eval_batches = 10;
    }

    println!("== GS orthogonal convolutions ==");

    // ---- exact structural checks (Eq. 2 / Eq. 3) --------------------------
    let mut rng = Rng::new(7);
    let (c, groups, h, w) = (16usize, 4usize, 2usize, 2usize);
    let grouped = ConvKernel::randn(c, c, 3, 0.2, &mut rng)
        .grouped(groups)
        .skew_symmetrize();
    let m = grouped.to_matrix(h, w);
    println!(
        "Eq. 2: grouped conv -> block-diagonal matrix: ||M + M^T||_F = {:.2e}",
        (&m + &m.t()).fro_norm()
    );
    let j = mat_exp(&m, 24);
    println!(
        "conv exponential Jacobian orthogonality: ||J^T J - I||_F = {:.2e}",
        j.orthogonality_error()
    );
    let shuffle = channel_shuffle_perm(&perm_paired(groups, c), h, w);
    let j2 = mat_exp(
        &ConvKernel::randn(c, c, 1, 0.2, &mut rng)
            .grouped(groups)
            .skew_symmetrize()
            .to_matrix(h, w),
        24,
    );
    let layer = j2.matmul(&shuffle.to_mat()).matmul(&j);
    println!(
        "GS-SOC layer (GrExp ∘ ChShuffle ∘ GrExp): orthogonality = {:.2e}",
        layer.orthogonality_error()
    );

    // ---- trained comparison (Table-3 cells) --------------------------------
    println!(
        "\ntraining SOC and GS-SOC(4,1) LipConvnets for {} steps each…",
        opts.steps
    );
    let variants = vec!["soc".to_string(), "g4_1_mmp_p".to_string()];
    let cells = table3::run_variants(&variants, &opts)?;
    for cell in &cells {
        println!(
            "  {:<12} params {:>8}  step {:>7.1} ms  acc {:>6.2}%  robust {:>6.2}%",
            cell.variant,
            cell.params,
            cell.step_seconds * 1e3,
            cell.accuracy,
            cell.robust_accuracy
        );
    }
    let soc = &cells[0];
    let gs = &cells[1];
    println!(
        "\nGS-SOC: {:.2}x fewer params, {:.2}x speedup per step",
        soc.params as f64 / gs.params as f64,
        soc.step_seconds / gs.step_seconds
    );
    println!("orthogonal_convnet OK");
    Ok(())
}
